"""Backend: the unified compilation entry point (paper sec. 4).

``Backend.create("jax").compile(fn, CompileOptions(level="O2"))`` is the
only sanctioned way to turn IR into something executable: the backend runs
the pass pipeline itself (at its default level unless the options say
otherwise), performs backend code generation, and memoizes the result in a
per-backend cache keyed on the canonical graph signature plus the options —
the serve/decode hot path compiles once per process, period.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Type

from ..core.function import Function
from ..core.passes import run_pipeline
from .compiled import CompiledFunction
from .options import CompileOptions, OptionsError


@dataclasses.dataclass
class CacheStats:
    hits: int
    misses: int
    size: int

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class Backend:
    """Base class: one instance per (backend name, backend opts).

    Subclasses implement :meth:`_codegen` — everything else (pipeline,
    cache, metadata attachment) is shared here.
    """

    name = "base"
    default_level = "O1"

    def __init__(self, **backend_opts):
        self.backend_opts = backend_opts
        self._cache: Dict[Tuple, CompiledFunction] = {}
        self._inflight: Dict[Tuple, threading.Event] = {}
        self._lock = threading.Lock()
        self.cache_hits = 0
        self.cache_misses = 0

    # -- registry / construction --------------------------------------------
    _REGISTRY: Dict[str, Type["Backend"]] = {}
    _INSTANCES: Dict[Tuple, "Backend"] = {}

    @classmethod
    def register(cls, backend_cls: Type["Backend"]) -> Type["Backend"]:
        cls._REGISTRY[backend_cls.name] = backend_cls
        return backend_cls

    @classmethod
    def available(cls) -> List[str]:
        return sorted(cls._REGISTRY)

    @classmethod
    def create(cls, name: str, *, fresh: bool = False,
               **backend_opts) -> "Backend":
        """Get the backend named ``name``.

        Instances are memoized per (name, backend_opts) so every caller in
        a process shares one compile cache; ``fresh=True`` bypasses the
        memo (isolated cache + counters, e.g. for benchmarks)."""
        if name not in cls._REGISTRY:
            raise KeyError(
                f"no backend {name!r}; available: {cls.available()}")
        if fresh:
            return cls._REGISTRY[name](**backend_opts)
        key = (name, tuple(sorted(backend_opts.items())))
        inst = cls._INSTANCES.get(key)
        if inst is None:
            inst = cls._INSTANCES[key] = cls._REGISTRY[name](**backend_opts)
        return inst

    # -- the one compile path ------------------------------------------------
    def compile(self, fn: Function,
                options: Optional[CompileOptions] = None) -> CompiledFunction:
        """Optimize + codegen ``fn``; memoized on (graph signature, options).

        The cache key is the canonical structural signature plus the
        parameter names (named-parameter calling must keep working on a
        hit), the *resolved* opt level, and the options.  Concurrent
        compiles of the same key are deduplicated: one thread builds, the
        rest wait and receive the same executable."""
        if options is None:
            options = CompileOptions()
        if not isinstance(options, CompileOptions):
            raise TypeError(
                f"options must be CompileOptions, got {type(options).__name__}"
                " — legacy **kwargs go through CompileOptions.from_kwargs()")
        n_params = len(fn.parameters)
        bad = [i for i in options.donate_argnums
               if not 0 <= i < n_params]
        if bad:
            raise OptionsError(
                f"donate_argnums {bad} out of range for {fn.name} "
                f"({n_params} parameters)")
        level = options.level or self.default_level
        key = (fn.signature(), tuple(p.name for p in fn.parameters),
               level, options.cache_key())
        while True:
            with self._lock:
                hit = self._cache.get(key)
                if hit is not None:
                    self.cache_hits += 1
                    return hit
                waiter = self._inflight.get(key)
                if waiter is None:
                    self._inflight[key] = threading.Event()
                    break  # this thread builds
            waiter.wait()  # another thread is building this key; retry
        try:
            opt_fn, report = run_pipeline(
                fn, level, compress_grads=options.compress_grads)
            call, raw, lower = self._codegen(opt_fn, options)
            compiled = CompiledFunction(
                opt_fn, call, backend=self.name, options=options,
                report=report, signature=key[0], raw=raw, lower=lower)
            with self._lock:
                self.cache_misses += 1
                self._cache[key] = compiled
            return compiled
        finally:
            with self._lock:
                self._inflight.pop(key).set()

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        """Backend code generation for an already-optimized graph.

        Returns ``(call, raw, lower)``: ``call`` takes/returns numpy,
        ``raw`` is the backend-native callable (or None to reuse ``call``),
        ``lower`` is the AOT hook (or None if unsupported)."""
        raise NotImplementedError

    # -- cache introspection -------------------------------------------------
    def cache_stats(self) -> CacheStats:
        with self._lock:
            return CacheStats(self.cache_hits, self.cache_misses,
                              len(self._cache))

    def clear_cache(self) -> None:
        with self._lock:
            self._cache.clear()
            self.cache_hits = 0
            self.cache_misses = 0


def register_backend(backend_cls: Type[Backend]) -> Type[Backend]:
    return Backend.register(backend_cls)


def available_backends() -> List[str]:
    return Backend.available()
