"""Backend: the unified compilation entry point (paper sec. 4).

``Backend.create("jax").compile(fn, CompileOptions(level="O2"))`` is the
only sanctioned way to turn IR into something executable: the backend runs
the pass pipeline itself (at its default level unless the options say
otherwise), performs backend code generation, and memoizes the result in a
per-backend cache keyed on the canonical graph signature plus the options —
the serve/decode hot path compiles once per process, period.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Type

from ..core.function import Function
from ..core.passes import run_pipeline
from .compiled import CompiledFunction
from .options import CompileOptions, OptionsError


@dataclasses.dataclass
class CacheStats:
    hits: int
    misses: int
    size: int
    # persistent (on-disk) layer — zero when no cache_dir is configured
    disk_hits: int = 0
    disk_misses: int = 0
    disk_evictions: int = 0
    # autotuner — reused records vs fresh sweeps
    autotune_hits: int = 0
    autotune_sweeps: int = 0

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0


class Backend:
    """Base class: one instance per (backend name, backend opts).

    Subclasses implement :meth:`_codegen` — everything else (pipeline,
    cache, metadata attachment) is shared here.
    """

    name = "base"
    default_level = "O1"

    def __init__(self, **backend_opts):
        self.backend_opts = backend_opts
        self._cache: Dict[Tuple, CompiledFunction] = {}
        self._inflight: Dict[Tuple, threading.Event] = {}
        self._lock = threading.Lock()
        self.cache_hits = 0
        self.cache_misses = 0
        self.autotune_hits = 0
        self.autotune_sweeps = 0
        self._autotune_mem: Dict[Any, Dict] = {}  # tuning records, in-process
        self._disk_caches: Dict[Tuple, Any] = {}  # (dir, budget) -> cache

    # -- registry / construction --------------------------------------------
    _REGISTRY: Dict[str, Type["Backend"]] = {}
    _INSTANCES: Dict[Tuple, "Backend"] = {}

    @classmethod
    def register(cls, backend_cls: Type["Backend"]) -> Type["Backend"]:
        cls._REGISTRY[backend_cls.name] = backend_cls
        return backend_cls

    @classmethod
    def available(cls) -> List[str]:
        return sorted(cls._REGISTRY)

    @classmethod
    def create(cls, name: str, *, fresh: bool = False,
               **backend_opts) -> "Backend":
        """Get the backend named ``name``.

        Instances are memoized per (name, backend_opts) so every caller in
        a process shares one compile cache; ``fresh=True`` bypasses the
        memo (isolated cache + counters, e.g. for benchmarks)."""
        if name not in cls._REGISTRY:
            raise KeyError(
                f"no backend {name!r}; available: {cls.available()}")
        if fresh:
            return cls._REGISTRY[name](**backend_opts)
        key = (name, tuple(sorted(backend_opts.items())))
        inst = cls._INSTANCES.get(key)
        if inst is None:
            inst = cls._INSTANCES[key] = cls._REGISTRY[name](**backend_opts)
        return inst

    # -- the one compile path ------------------------------------------------
    def compile(self, fn: Function,
                options: Optional[CompileOptions] = None) -> CompiledFunction:
        """Optimize + codegen ``fn``; memoized on (graph signature, options).

        The cache key is the canonical structural signature plus the
        parameter names (named-parameter calling must keep working on a
        hit), the *resolved* opt level, and the options.  Concurrent
        compiles of the same key are deduplicated: one thread builds, the
        rest wait and receive the same executable.

        ``options.autotune=True`` first resolves the attention knobs via
        :mod:`repro.backend.autotune` (cached tuning record, else a sweep),
        then compiles with the concrete winner.  When a cache dir is
        configured (``options.cache_dir`` or ``$REPRO_CACHE_DIR``) an
        in-memory miss consults :class:`~repro.backend.diskcache.
        DiskCompileCache` before running the pass pipeline: a disk hit
        rehydrates the optimized graph + PipelineReport + metadata and only
        re-runs backend codegen (or reloads an AOT-serialized executable)."""
        if options is None:
            options = CompileOptions()
        if not isinstance(options, CompileOptions):
            raise TypeError(
                f"options must be CompileOptions, got {type(options).__name__}"
                " — legacy **kwargs go through CompileOptions.from_kwargs()")
        n_params = len(fn.parameters)
        bad = [i for i in options.donate_argnums
               if not 0 <= i < n_params]
        if bad:
            raise OptionsError(
                f"donate_argnums {bad} out of range for {fn.name} "
                f"({n_params} parameters)")
        if options.autotune:
            from . import autotune as _autotune
            options = _autotune.resolve(self, fn, options)
        level = options.level or self.default_level
        key = (fn.signature(), tuple(p.name for p in fn.parameters),
               level, options.cache_key())
        while True:
            with self._lock:
                hit = self._cache.get(key)
                if hit is not None:
                    self.cache_hits += 1
                    return hit
                waiter = self._inflight.get(key)
                if waiter is None:
                    self._inflight[key] = threading.Event()
                    break  # this thread builds
            waiter.wait()  # another thread is building this key; retry
        try:
            compiled = self._build(fn, options, level, key)
            with self._lock:
                self.cache_misses += 1
                self._cache[key] = compiled
            return compiled
        finally:
            with self._lock:
                self._inflight.pop(key).set()

    def _build(self, fn: Function, options: CompileOptions, level: str,
               key: Tuple) -> CompiledFunction:
        """Build one executable: disk-cache rehydrate, else full pipeline."""
        from . import diskcache
        disk = self._disk_for(options)
        dkey = None
        if disk is not None:
            dkey = diskcache.entry_key(key[0], key[1], level, options,
                                       self.name, self.backend_opts)
        if dkey is not None:
            entry = disk.load(dkey)
            if entry is not None:
                hydrated = self._from_entry(entry, options, key[0])
                if hydrated is not None:
                    return hydrated
                # the entry read fine but wouldn't hydrate (e.g. alien
                # graph rejected by codegen) — the full pipeline runs, so
                # reporting a disk hit would let warm-start CI gates pass
                # on a run that re-paid everything
                disk.hits -= 1
                disk.misses += 1
        opt_fn, report = run_pipeline(
            fn, level, compress_grads=options.compress_grads,
            fuse={"swiglu": options.fuse_swiglu,
                  "norm_matmul": options.fuse_norm_matmul,
                  "rotary_qkv": options.fuse_rotary_qkv},
            partition=self._partition_pass(options))
        call, raw, lower = self._codegen(opt_fn, options)
        compiled = CompiledFunction(
            opt_fn, call, backend=self.name, options=options,
            report=report, signature=key[0], raw=raw, lower=lower)
        if dkey is not None:
            disk.store(
                dkey, fn=opt_fn, report=report, level=level,
                backend_name=self.name, options=options,
                memory_plan=compiled.memory_plan, cost=compiled.cost,
                executable=self._export_executable(compiled, options))
        return compiled

    def _partition_pass(self, options: CompileOptions):
        """The configured PartitionGraph pass for these options (None when
        not partitioning).  ``mode='shardmap'`` cuts the graph explicitly;
        ``mode='pjit'`` leaves partitioning to GSPMD via the policy's
        shardings, so no pass runs there."""
        if options.partition is None or options.mode != "shardmap":
            return None
        from ..core.passes import PartitionGraph
        from .sharding import mesh_axis_sizes, partition_profile
        profile = partition_profile(options.partition)
        if options.mesh_shape is not None:
            sizes = profile.axis_sizes(options.mesh_shape)
        else:
            sizes = {a: n for a, n in mesh_axis_sizes(options.mesh).items()
                     if a in profile.axes}
        return PartitionGraph.from_profile_sizes(profile, sizes)

    def _from_entry(self, entry: Dict, options: CompileOptions,
                    signature: str) -> Optional[CompiledFunction]:
        """Rehydrate a disk entry: codegen the stored *optimized* graph
        (the pipeline is skipped — that's the point), preferring the AOT
        executable when the backend can load one."""
        opt_fn = entry["function"]
        loaded = None
        if entry.get("executable"):
            loaded = self._load_executable(entry["executable"], opt_fn,
                                           options)
        if loaded is None:
            try:
                loaded = self._codegen(opt_fn, options)
            except Exception:
                return None  # alien graph; fall back to a full build
        call, raw, lower = loaded
        # memory plan stays lazy: the stored totals are introspection-only
        # (cache_tool), and a plan without its buffer assignments would
        # silently disable the interpreter's arena mode — recomputing from
        # the rehydrated graph gives the identical full plan
        cost = None
        if entry.get("cost"):
            from ..core.cost import Cost
            c = entry["cost"]
            cost = Cost(flops=float(c["flops"]), bytes=float(c["bytes"]),
                        by_op=c.get("by_op"))
        return CompiledFunction(
            opt_fn, call, backend=self.name, options=options,
            report=entry["report"], signature=signature, raw=raw,
            lower=lower, cost=cost, from_disk=True)

    # -- persistence hooks ---------------------------------------------------
    def _disk_for(self, options: CompileOptions):
        """The DiskCompileCache for these options, or None (disabled)."""
        from . import diskcache
        root = diskcache.resolve_dir(options)
        if root is None:
            return None
        budget = diskcache.resolve_budget(options)
        with self._lock:
            dc = self._disk_caches.get((root, budget))
            if dc is None:
                dc = diskcache.DiskCompileCache(root, budget)
                self._disk_caches[(root, budget)] = dc
        return dc

    def _export_executable(self, compiled: CompiledFunction,
                           options: CompileOptions) -> Optional[bytes]:
        """AOT-serialize ``compiled`` for the disk cache (None = can't)."""
        return None

    def _load_executable(self, data: bytes, fn: Function,
                         options: CompileOptions):
        """Inverse of :meth:`_export_executable`; None falls back to
        re-running codegen on the deserialized graph."""
        return None

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        """Backend code generation for an already-optimized graph.

        Returns ``(call, raw, lower)``: ``call`` takes/returns numpy,
        ``raw`` is the backend-native callable (or None to reuse ``call``),
        ``lower`` is the AOT hook (or None if unsupported)."""
        raise NotImplementedError

    # -- cache introspection -------------------------------------------------
    def cache_stats(self) -> CacheStats:
        with self._lock:
            disks = list(self._disk_caches.values())
            return CacheStats(
                self.cache_hits, self.cache_misses, len(self._cache),
                disk_hits=sum(d.hits for d in disks),
                disk_misses=sum(d.misses for d in disks),
                disk_evictions=sum(d.evictions for d in disks),
                autotune_hits=self.autotune_hits,
                autotune_sweeps=self.autotune_sweeps)

    def clear_cache(self) -> None:
        """Reset the in-memory cache and counters (disk entries persist —
        that is their job; use DiskCompileCache.clear/cache_tool.py)."""
        with self._lock:
            self._cache.clear()
            self.cache_hits = 0
            self.cache_misses = 0
            self.autotune_hits = 0
            self.autotune_sweeps = 0
            self._disk_caches.clear()


def register_backend(backend_cls: Type[Backend]) -> Type[Backend]:
    return Backend.register(backend_cls)


def available_backends() -> List[str]:
    return Backend.available()
