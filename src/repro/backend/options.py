"""CompileOptions: the one declarative knob set for every backend.

Everything that used to be scattered across legacy ``Transformer.compile``
kwargs and the emitter context lives here as a frozen, validated dataclass.
Options
are part of the compile-cache key (see :meth:`CompileOptions.cache_key`), so
two compiles of the same Function with the same options share an executable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

_LEVELS = ("O0", "O1", "O2")
_MODES = ("jit", "shardmap", "pjit")
_ATTN_IMPLS = ("auto", "naive", "chunked")


class OptionsError(ValueError):
    """Raised for invalid CompileOptions field combinations."""


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """Declarative compilation options, uniform across backends.

    ``level=None`` means "use the backend's default level" (O1 for jax,
    O0 for the interpreter).  Fields irrelevant to a backend are ignored by
    it (e.g. ``arena`` on jax, ``mesh`` on the interpreter).
    """

    # pass pipeline
    level: Optional[str] = None          # None | 'O0' | 'O1' | 'O2'
    compress_grads: bool = False         # O2 extra: bf16 AllReduce wires
    # per-compound fusion gates (O2 only; autotune can flip each one so a
    # losing fused kernel never ships)
    fuse_swiglu: bool = True
    fuse_norm_matmul: bool = True
    fuse_rotary_qkv: bool = True

    # jax emission / partitioning
    mode: str = "jit"                    # 'jit' | 'shardmap' | 'pjit'
    mesh: Any = None                     # jax Mesh (pjit mode)
    axis_rules: Any = None               # logical axis -> mesh axes
    # graph partitioning (PR 10): `partition` names a profile from
    # repro.backend.sharding (e.g. 'tp'); with mode='shardmap' the
    # PartitionGraph pass cuts the graph and inserts explicit collective
    # nodes, with mode='pjit' the profile's policy derives in_shardings/
    # axis_rules so callers never hand-build them.  `mesh_shape` sizes
    # the device mesh (axis names come from the profile) when no `mesh`
    # object is passed — being plain ints, it disk-caches.
    partition: Optional[str] = None
    mesh_shape: Optional[Tuple[int, ...]] = None
    use_pallas: bool = False             # compound ops as Pallas kernels
    interpret_pallas: bool = True        # Pallas interpret mode (CPU-safe)
    remat_scan: bool = False             # checkpoint scan bodies
    attn_impl: str = "auto"              # 'auto' | 'naive' | 'chunked'
    attn_chunk: int = 1024
    # matmul-family Pallas tile shapes (matmul / SwiGLU / NormMatmul);
    # autotune sweeps these per (backend, shape-signature)
    mm_bm: int = 256
    mm_bn: int = 256
    mm_bk: int = 512
    static_jit: bool = True              # wrap emission in jax.jit
    in_shardings: Any = None
    out_shardings: Any = None
    donate_argnums: Tuple[int, ...] = ()

    # interpreter
    arena: Any = None                    # MemoryPlan | True (plan one) | None

    # persistence / tuning (NOT part of the executable's identity: they say
    # where artifacts live and how knobs get resolved, never what runs)
    cache_dir: Optional[str] = None      # on-disk compile cache root
    cache_budget_bytes: Optional[int] = None  # LRU eviction budget
    autotune: bool = False               # resolve attn knobs via the tuner

    def __post_init__(self):
        if self.level is not None and self.level not in _LEVELS:
            raise OptionsError(
                f"level must be one of {_LEVELS} or None, got {self.level!r}")
        if self.mode not in _MODES:
            raise OptionsError(
                f"mode must be one of {_MODES}, got {self.mode!r}")
        if self.attn_impl not in _ATTN_IMPLS:
            raise OptionsError(
                f"attn_impl must be one of {_ATTN_IMPLS}, "
                f"got {self.attn_impl!r}")
        if not isinstance(self.attn_chunk, int) or self.attn_chunk <= 0:
            raise OptionsError(
                f"attn_chunk must be a positive int, got {self.attn_chunk!r}")
        for name in ("mm_bm", "mm_bn", "mm_bk"):
            v = getattr(self, name)
            if not isinstance(v, int) or isinstance(v, bool) or v <= 0:
                raise OptionsError(
                    f"{name} must be a positive int, got {v!r}")
        for name in ("fuse_swiglu", "fuse_norm_matmul", "fuse_rotary_qkv"):
            if not isinstance(getattr(self, name), bool):
                raise OptionsError(
                    f"{name} must be a bool, got {getattr(self, name)!r}")
        if self.mode == "pjit" and self.mesh is None:
            raise OptionsError("mode='pjit' requires a mesh")
        if self.mode == "pjit" and not self.static_jit:
            raise OptionsError("mode='pjit' requires static_jit=True")
        if self.partition is not None:
            from .sharding import PARTITION_PROFILES
            if self.partition not in PARTITION_PROFILES:
                raise OptionsError(
                    f"partition must be one of {PARTITION_PROFILES} or "
                    f"None, got {self.partition!r}")
            if self.mode == "jit":
                raise OptionsError(
                    "partition requires mode='shardmap' (explicit "
                    "collectives) or mode='pjit' (GSPMD)")
            if self.mesh is None and self.mesh_shape is None:
                raise OptionsError(
                    "partition requires a mesh or mesh_shape")
        if self.mesh_shape is not None:
            try:
                shape = tuple(int(s) for s in self.mesh_shape)
            except (TypeError, ValueError):
                raise OptionsError(
                    f"mesh_shape must be a tuple of ints, got "
                    f"{self.mesh_shape!r}") from None
            if not shape or any(s < 1 for s in shape):
                raise OptionsError(
                    f"mesh_shape dims must be >= 1, got {self.mesh_shape!r}")
            object.__setattr__(self, "mesh_shape", shape)
            if self.partition is None:
                raise OptionsError("mesh_shape requires a partition profile "
                                   "(it names the mesh axes)")
        try:
            donate = tuple(int(i) for i in self.donate_argnums)
        except TypeError:
            raise OptionsError(
                f"donate_argnums must be a sequence of ints, "
                f"got {self.donate_argnums!r}") from None
        object.__setattr__(self, "donate_argnums", donate)
        if self.cache_dir is not None and not isinstance(self.cache_dir, str):
            raise OptionsError(
                f"cache_dir must be a str or None, got {self.cache_dir!r}")
        if self.cache_budget_bytes is not None and (
                not isinstance(self.cache_budget_bytes, int)
                or self.cache_budget_bytes <= 0):
            raise OptionsError(
                f"cache_budget_bytes must be a positive int or None, "
                f"got {self.cache_budget_bytes!r}")

    # fields that never participate in cache keys: `level` keys by its
    # *resolved* value, and the persistence/tuning knobs affect where
    # artifacts are stored (or how knobs are picked), not what executes.
    _NON_IDENTITY = ("level", "cache_dir", "cache_budget_bytes", "autotune")

    # -- compile-cache keying ------------------------------------------------
    def cache_key(self) -> Tuple:
        """A hashable, collision-safe token for these options.

        Primitive fields key by value; opaque objects (meshes, shardings,
        memory plans) key by identity — a distinct object is a cache miss,
        never a false hit.  ``level`` is excluded: the backend keys on the
        *resolved* level, so ``level=None`` and an explicit
        ``level=<backend default>`` share an executable.  ``cache_dir``/
        ``cache_budget_bytes``/``autotune`` are excluded too (see
        ``_NON_IDENTITY``)."""
        return tuple((f.name, _token(getattr(self, f.name)))
                     for f in dataclasses.fields(self)
                     if f.name not in self._NON_IDENTITY)

    def stable_token(self) -> Optional[Tuple]:
        """Like :meth:`cache_key` but process-stable, for the *disk* cache.

        Opaque objects (shardings, memory plans) key by ``id()``
        in-process, which is meaningless across processes — options
        carrying any return ``None``, meaning "not disk-cacheable".
        Meshes are the exception: a mesh is identified by its axis
        names, shape, and device kind, all process-stable, so
        shardmap/tp compiles hit the disk cache and warm replicas skip
        the pipeline."""
        out = []
        for f in dataclasses.fields(self):
            if f.name in self._NON_IDENTITY:
                continue
            tok = _stable_token(getattr(self, f.name))
            if tok is _UNSTABLE:
                return None
            out.append((f.name, tok))
        return tuple(out)

    def replace(self, **changes) -> "CompileOptions":
        return dataclasses.replace(self, **changes)

    @classmethod
    def from_kwargs(cls, **legacy) -> "CompileOptions":
        """Build options from legacy ``Transformer.compile(**kwargs)`` names.

        Unknown keys are ignored (the legacy API ignored them too)."""
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in legacy.items() if k in known})


def _token(v: Any):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (tuple, list)):
        return (type(v).__name__,) + tuple(_token(x) for x in v)
    return ("obj", type(v).__name__, id(v))


_UNSTABLE = object()


def _stable_token(v: Any):
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (tuple, list)):
        toks = tuple(_stable_token(x) for x in v)
        if any(t is _UNSTABLE for t in toks):
            return _UNSTABLE
        return (type(v).__name__,) + toks
    tok = _mesh_token(v)
    if tok is not None:
        return tok
    return _UNSTABLE


def _mesh_token(v: Any):
    """A process-stable token for a jax Mesh (duck-typed so options never
    import jax): (axis names, mesh shape, device kinds)."""
    axis_names = getattr(v, "axis_names", None)
    devices = getattr(v, "devices", None)
    if axis_names is None or devices is None or not hasattr(devices, "shape"):
        return None
    try:
        kinds = tuple(sorted({f"{d.platform}:{d.device_kind}"
                              for d in devices.flat}))
        return ("mesh", tuple(str(a) for a in axis_names),
                tuple(int(s) for s in devices.shape), kinds)
    except Exception:
        return None
