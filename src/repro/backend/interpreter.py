"""Interpreter backend: the numpy reference executor behind the Backend API.

Default level is O0 (run exactly the graph it was given): the interpreter
is the semantic oracle the other backends are tested against, and arena
execution (``options.arena``) needs node identity preserved.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

from ..core.function import Function
from ..transformers.interpreter import evaluate
from .base import Backend, register_backend
from .options import CompileOptions


@register_backend
class InterpreterBackend(Backend):
    """Pure-numpy reference executor (with optional planned-arena mode).

    Participates in the persistent disk cache like any backend (the
    stored optimized graph + PipelineReport skip the pipeline on a cold
    process) but has no AOT executable format — rehydration re-enters
    :meth:`_codegen`, which is just a closure over ``evaluate``."""

    name = "interpreter"
    default_level = "O0"

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        arena = options.arena
        if arena is True:
            from ..core.passes import plan_memory
            arena = plan_memory(fn)

        def call(*args):
            return evaluate(fn, list(args), arena=arena)

        return call, None, None
