"""JAX/XLA backend: pipeline + IR emission + jit behind the Backend API.

Code generation itself lives in :mod:`repro.transformers.jax_backend`
(the emitter table); this module is the sanctioned entry that composes it
with the pass pipeline, sharding options, and the compile cache.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from ..core.function import Function
from ..transformers.jax_backend import EmitCtx, emit_callable
from .base import Backend, register_backend
from .options import CompileOptions


@register_backend
class JaxBackend(Backend):
    """Compiles IR -> jitted XLA executable (optionally pjit-partitioned)."""

    name = "jax"
    default_level = "O1"

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        import jax

        ctx = EmitCtx(mode=options.mode, mesh=options.mesh,
                      use_pallas=options.use_pallas,
                      remat_scan=options.remat_scan,
                      interpret_pallas=options.interpret_pallas,
                      attn_impl=options.attn_impl,
                      attn_chunk=options.attn_chunk,
                      axis_rules=options.axis_rules)
        run = emit_callable(fn, ctx)
        lower = None
        if options.static_jit:
            kw = {}
            if options.in_shardings is not None:
                kw["in_shardings"] = options.in_shardings
            if options.out_shardings is not None:
                kw["out_shardings"] = options.out_shardings
            run = jax.jit(run, donate_argnums=options.donate_argnums, **kw)
            lower = run.lower

        def call(*args):
            return [np.asarray(o) for o in run(*args)]

        return call, run, lower

    # -- persistent-cache AOT hooks ------------------------------------------
    @staticmethod
    def _exportable(options: CompileOptions) -> bool:
        """AOT serialization covers the plain single-device jit path only:
        meshes/shardings don't rehydrate portably, and an exported module
        drops donation (a donated hot loop must re-jit from the graph)."""
        return (options.static_jit and options.mode == "jit"
                and options.mesh is None and options.in_shardings is None
                and options.out_shardings is None
                and not options.donate_argnums)

    def _export_executable(self, compiled, options: CompileOptions
                           ) -> Optional[bytes]:
        if not self._exportable(options):
            return None
        try:
            import jax
            from jax import export as jexport

            specs = [jax.ShapeDtypeStruct(t.shape, np.dtype(t.dtype))
                     for t in compiled.function.in_types]
            return jexport.export(compiled.raw)(*specs).serialize()
        except Exception:
            return None  # best-effort: the graph entry alone is still a win

    def _load_executable(self, data: bytes, fn: Function,
                         options: CompileOptions):
        if not self._exportable(options):
            return None
        try:
            import jax
            from jax import export as jexport

            exported = jexport.deserialize(bytearray(data))
            # a blob lowered on another platform (cache dir shared between
            # a GPU box and a CPU CI runner) would only fail at first call,
            # inside the serve loop — reject it here and fall back to
            # re-emitting from the stored graph instead
            platforms = {p.lower() for p in exported.platforms}
            if jax.default_backend().lower() not in platforms:
                return None
            run = jax.jit(exported.call)

            def call(*args):
                return [np.asarray(o) for o in run(*args)]

            return call, run, run.lower
        except Exception:
            return None  # stale/alien blob: re-emit from the stored graph
