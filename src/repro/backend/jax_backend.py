"""JAX/XLA backend: pipeline + IR emission + jit behind the Backend API.

Code generation itself lives in :mod:`repro.transformers.jax_backend`
(the emitter table); this module is the sanctioned entry that composes it
with the pass pipeline, sharding options, and the compile cache.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from ..core.function import Function
from ..transformers.jax_backend import EmitCtx, emit_callable
from .base import Backend, register_backend
from .options import CompileOptions


@register_backend
class JaxBackend(Backend):
    """Compiles IR -> jitted XLA executable (optionally pjit-partitioned)."""

    name = "jax"
    default_level = "O1"

    def __init__(self, **backend_opts):
        device = backend_opts.pop("device", None)
        if backend_opts:
            raise TypeError(
                f"unknown jax backend opts {sorted(backend_opts)}; "
                f"supported: ['device']")
        self.device = (self._resolve_device(device)
                       if device is not None else None)
        opts = {}
        if self.device is not None:
            # normalize to "platform:id" whichever spelling the caller
            # used, so the instance memo and the disk-cache entry key see
            # one stable string per physical device
            opts["device"] = f"{self.device.platform}:{self.device.id}"
        super().__init__(**opts)

    @staticmethod
    def _resolve_device(spec):
        """``device=`` opt -> a concrete ``jax.Device``.

        Accepts a ``jax.Device``, an index into ``jax.devices()``, or a
        ``"platform[:index]"`` string (``"cpu"``, ``"cpu:1"``, ``"gpu:0"``).
        Unknown ids fail here, at ``Backend.create`` time, with the
        available devices listed — not at first dispatch."""
        import jax

        devices = jax.devices()
        avail = [f"{d.platform}:{d.id}" for d in devices]
        if isinstance(spec, jax.Device):
            if spec not in devices:
                raise ValueError(
                    f"device {spec!r} is not attached; available: {avail}")
            return spec
        if isinstance(spec, (int, np.integer)) \
                and not isinstance(spec, bool):
            if not 0 <= int(spec) < len(devices):
                raise ValueError(
                    f"device index {int(spec)} out of range; "
                    f"available: {avail}")
            return devices[int(spec)]
        if isinstance(spec, str):
            plat, _, idx_s = spec.lower().partition(":")
            if idx_s and not idx_s.isdigit():
                raise ValueError(
                    f"malformed device {spec!r} (want 'platform[:index]'); "
                    f"available: {avail}")
            idx = int(idx_s) if idx_s else 0
            matches = [d for d in devices if d.platform.lower() == plat]
            if idx < len(matches):
                return matches[idx]
            raise ValueError(
                f"unknown device {spec!r}; available: {avail}")
        raise TypeError(
            f"device must be a jax.Device, int index, or "
            f"'platform[:index]' string, got {type(spec).__name__}")

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        import jax

        ctx = EmitCtx(mode=options.mode, mesh=options.mesh,
                      use_pallas=options.use_pallas,
                      remat_scan=options.remat_scan,
                      interpret_pallas=options.interpret_pallas,
                      attn_impl=options.attn_impl,
                      attn_chunk=options.attn_chunk,
                      mm_bm=options.mm_bm, mm_bn=options.mm_bn,
                      mm_bk=options.mm_bk,
                      axis_rules=options.axis_rules)
        run = emit_callable(fn, ctx)
        mesh = self._shardmap_mesh(fn, options)
        if mesh is not None:
            run = self._wrap_shard_map(run, fn, mesh)
        lower = None
        if options.static_jit:
            kw = {}
            if options.in_shardings is not None:
                kw["in_shardings"] = options.in_shardings
            if options.out_shardings is not None:
                kw["out_shardings"] = options.out_shardings
            if self.device is not None and "out_shardings" not in kw \
                    and mesh is None:
                # pin via a single-device output sharding (the supported
                # spelling — jit's `device=` kwarg is deprecated): inputs
                # follow the outputs' placement, so donated KV chains
                # stay resident on the pinned device
                kw["out_shardings"] = \
                    jax.sharding.SingleDeviceSharding(self.device)
            run = jax.jit(run, donate_argnums=options.donate_argnums, **kw)
            lower = run.lower

        def call(*args):
            return [np.asarray(o) for o in run(*args)]

        return call, run, lower

    @staticmethod
    def _shardmap_mesh(fn: Function, options: CompileOptions):
        """The mesh to shard_map a partitioned graph over, or None.

        Active only when the PartitionGraph pass actually ran (parameters
        carry ``pspec`` attrs) — plain ``mode='shardmap'`` compiles with
        hand-written collectives (tests, manual wraps) are left alone."""
        if options.mode != "shardmap":
            return None
        if not any("pspec" in p.attrs for p in fn.parameters):
            return None
        from .sharding import mesh_for_options
        return mesh_for_options(options)

    @staticmethod
    def _wrap_shard_map(run: Callable, fn: Function, mesh):
        """Wrap the emitted callable in shard_map with the specs the
        partition pass stamped on the graph.  Callers keep passing global
        arrays; jit splits them per ``in_specs`` (and donation keeps the
        sharded KV chain device-resident across dispatches)."""
        from jax.sharding import PartitionSpec
        try:
            from jax.experimental.shard_map import shard_map
        except ImportError:  # newer jax spells it jax.shard_map
            from jax import shard_map

        def spec_of(p):
            ps = p.attrs.get("pspec") or (None,) * len(p.out_types[0].shape)
            return PartitionSpec(*ps)

        in_specs = tuple(spec_of(p) for p in fn.parameters)
        out_specs = []
        for r in fn.results:
            ps = r.node.attrs.get("out_pspecs")
            spec = ps[r.index] if ps else (None,) * len(r.shape)
            out_specs.append(PartitionSpec(*spec))

        def as_tuple(*args):
            return tuple(run(*args))

        try:
            wrapped = shard_map(as_tuple, mesh=mesh, in_specs=in_specs,
                                out_specs=tuple(out_specs), check_rep=False)
        except TypeError:  # check_rep renamed/removed
            wrapped = shard_map(as_tuple, mesh=mesh, in_specs=in_specs,
                                out_specs=tuple(out_specs))

        def as_list(*args):
            return list(wrapped(*args))

        return as_list

    # -- persistent-cache AOT hooks ------------------------------------------
    def _exportable(self, options: CompileOptions) -> bool:
        """AOT serialization covers the plain single-device jit path only:
        meshes/shardings don't rehydrate portably, an exported module
        drops donation (a donated hot loop must re-jit from the graph),
        and a blob loaded on a device-pinned backend would silently run
        on the default device instead of the pinned one."""
        return (self.device is None
                and options.static_jit and options.mode == "jit"
                and options.mesh is None and options.in_shardings is None
                and options.out_shardings is None
                and not options.donate_argnums)

    def _export_executable(self, compiled, options: CompileOptions
                           ) -> Optional[bytes]:
        if not self._exportable(options):
            return None
        try:
            import jax
            from jax import export as jexport

            specs = [jax.ShapeDtypeStruct(t.shape, np.dtype(t.dtype))
                     for t in compiled.function.in_types]
            return jexport.export(compiled.raw)(*specs).serialize()
        except Exception:
            return None  # best-effort: the graph entry alone is still a win

    def _load_executable(self, data: bytes, fn: Function,
                         options: CompileOptions):
        if not self._exportable(options):
            return None
        try:
            import jax
            from jax import export as jexport

            exported = jexport.deserialize(bytearray(data))
            # a blob lowered on another platform (cache dir shared between
            # a GPU box and a CPU CI runner) would only fail at first call,
            # inside the serve loop — reject it here and fall back to
            # re-emitting from the stored graph instead
            platforms = {p.lower() for p in exported.platforms}
            if jax.default_backend().lower() not in platforms:
                return None
            run = jax.jit(exported.call)

            def call(*args):
                return [np.asarray(o) for o in run(*args)]

            return call, run, run.lower
        except Exception:
            return None  # stale/alien blob: re-emit from the stored graph
