"""JAX/XLA backend: pipeline + IR emission + jit behind the Backend API.

Code generation itself lives in :mod:`repro.transformers.jax_backend`
(the emitter table); this module is the sanctioned entry that composes it
with the pass pipeline, sharding options, and the compile cache.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import numpy as np

from ..core.function import Function
from ..transformers.jax_backend import EmitCtx, emit_callable
from .base import Backend, register_backend
from .options import CompileOptions


@register_backend
class JaxBackend(Backend):
    """Compiles IR -> jitted XLA executable (optionally pjit-partitioned)."""

    name = "jax"
    default_level = "O1"

    def _codegen(self, fn: Function, options: CompileOptions
                 ) -> Tuple[Callable, Optional[Callable], Optional[Callable]]:
        import jax

        ctx = EmitCtx(mode=options.mode, mesh=options.mesh,
                      use_pallas=options.use_pallas,
                      remat_scan=options.remat_scan,
                      interpret_pallas=options.interpret_pallas,
                      attn_impl=options.attn_impl,
                      attn_chunk=options.attn_chunk,
                      axis_rules=options.axis_rules)
        run = emit_callable(fn, ctx)
        lower = None
        if options.static_jit:
            kw = {}
            if options.in_shardings is not None:
                kw["in_shardings"] = options.in_shardings
            if options.out_shardings is not None:
                kw["out_shardings"] = options.out_shardings
            run = jax.jit(run, donate_argnums=options.donate_argnums, **kw)
            lower = run.lower

        def call(*args):
            return [np.asarray(o) for o in run(*args)]

        return call, run, lower
