"""The one sharding API: policies, meshes, and partition profiles.

Everything distribution-related that callers used to assemble from three
modules (``runtime/distributed.py`` policies, ``launch/mesh.py`` mesh
construction, ``launch/shardings.py`` per-graph glue) lives here, next to
:class:`~repro.backend.options.CompileOptions` — the object that actually
consumes it.  Graphs carry *logical* axis names (builders tag every
parameter and input); this module maps them onto mesh axes, either as
pjit PartitionSpecs (``graph_shardings``/``train_step_shardings``) or as
the per-logical-axis rule table the :class:`PartitionGraph` pass uses to
cut a graph into per-device programs (``partition_profile``).

The old modules remain as one-release deprecation shims re-exporting
from here (policed by ``scripts/check_deprecated.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class ParamInfo:
    """Logical description of one parameter tensor."""

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    logical_axes: Tuple[Optional[str], ...]  # one entry per dim


# logical axis -> mesh axes, per policy profile
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),  # batch dims (pod filtered out on 1-pod mesh)
    "vocab": ("model",),
    "embed": ("zero",),        # ZeRO/FSDP shard of the embedding dim
    "ffn": ("model",),         # TP shard of the hidden dim
    "heads": ("model",),
    "kv_heads": (),            # few kv heads: keep replicated
    "kv_seq": ("model",),      # decode KV caches: sequence-shard on model
    "experts": ("expert",),    # resolved to real axes by the profile
    "expert_ffn": (),
    "layers": (),              # stacked-layer leading dim stays unsharded
    "conv": (),
    "seq": (),
    "state": (),
    None: (),
}


@dataclasses.dataclass
class ShardingPolicy:
    """Maps logical axes to mesh axes and produces PartitionSpecs."""

    rules: Dict[str, Tuple[str, ...]]
    zero_axes: Tuple[str, ...] = ("data",)   # FSDP axes for 'embed'-tagged dims
    expert_axes: Tuple[str, ...] = ("model",)
    batch_axes: Tuple[str, ...] = ("data",)  # + 'pod' when present

    def resolve(self, logical: Optional[str]) -> Tuple[str, ...]:
        axes = self.rules.get(logical, ())
        out = []
        for a in axes:
            if a == "expert":
                out.extend(self.expert_axes)
            elif a == "zero":
                out.extend(self.zero_axes)
            else:
                out.append(a)
        return tuple(out)

    def spec_for(self, info: ParamInfo, mesh) -> "jax.sharding.PartitionSpec":
        from jax.sharding import PartitionSpec

        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used = set()
        entries: List[Any] = []
        for dim, logical in zip(info.shape, info.logical_axes):
            axes = [a for a in self.resolve(logical)
                    if a in sizes and a not in used]
            # keep only axes that divide the dim evenly
            keep: List[str] = []
            prod = 1
            for a in axes:
                if dim % (prod * sizes[a]) == 0:
                    keep.append(a)
                    prod *= sizes[a]
            used.update(keep)
            if not keep:
                entries.append(None)
            elif len(keep) == 1:
                entries.append(keep[0])
            else:
                entries.append(tuple(keep))
        return PartitionSpec(*entries)

    def sharding_for(self, info: ParamInfo, mesh):
        from jax.sharding import NamedSharding

        return NamedSharding(mesh, self.spec_for(info, mesh))

    def batch_spec(self, mesh, rank: int = 2):
        """Batch tensors: leading dim over (pod+)data axes."""
        from jax.sharding import PartitionSpec

        axes = tuple(a for a in ("pod",) + tuple(self.batch_axes)
                     if a in mesh.axis_names)
        axes = tuple(dict.fromkeys(axes))
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        return PartitionSpec(lead, *([None] * (rank - 1)))

    def replicated(self, mesh):
        from jax.sharding import NamedSharding, PartitionSpec

        return NamedSharding(mesh, PartitionSpec())

    def as_rules(self) -> Dict[str, Tuple[str, ...]]:
        """Flat logical->mesh-axes table for the ShardingConstraint
        emitter (jax_backend): every known logical name, resolved."""
        return {k: self.resolve(k) for k in self.rules if k is not None}

    def input_sharding(self, mesh, shape, logical_spec):
        """NamedSharding for a data input from its logical per-dim spec."""
        info = ParamInfo("_input", tuple(shape), None, tuple(logical_spec))
        return self.sharding_for(info, mesh)


def policy_for(profile: str = "default", mesh=None) -> ShardingPolicy:
    """Profiles implement per-arch parallelism mixes (DESIGN.md sec. 5)."""
    rules = dict(DEFAULT_RULES)
    if profile == "default":
        return ShardingPolicy(rules)
    if profile == "zero3_pod":
        # shard the FSDP ('embed') dims across pods too: ZeRO-3 over all chips
        return ShardingPolicy(rules, zero_axes=("pod", "data"))
    if profile == "expert_parallel":
        # MoE: experts across data*model (EP), used when E divides the product
        return ShardingPolicy(rules, expert_axes=("data", "model"))
    if profile == "zero3_pod_ep":
        # deepseek-v3: ZeRO-3 across pods + 256-way expert parallelism
        return ShardingPolicy(rules, zero_axes=("pod", "data"),
                              expert_axes=("data", "model"))
    if profile == "expert_tp":
        # MoE with few experts: shard inside each expert instead
        rules["experts"] = ()
        rules["expert_ffn"] = ("model",)
        return ShardingPolicy(rules)
    raise KeyError(f"unknown sharding profile {profile}")


# per-arch parallelism profile (DESIGN.md sec. 5)
ARCH_PROFILES: Dict[str, str] = {
    "deepseek-v3-671b": "zero3_pod_ep",
    "mixtral-8x22b": "expert_tp",
}


def policy_for_arch(arch_name: str) -> ShardingPolicy:
    return policy_for(ARCH_PROFILES.get(arch_name, "default"))


def infos_to_shardings(policy: ShardingPolicy, infos: Sequence[ParamInfo], mesh):
    return [policy.sharding_for(i, mesh) for i in infos]


# ---------------------------------------------------------------------------
# partition profiles: the PartitionGraph pass's view of a policy
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PartitionProfile:
    """What the :class:`~repro.core.passes.partition.PartitionGraph` pass
    needs from ``CompileOptions(partition=..., mesh_shape=...)``: mesh
    axis names (positional, matching ``mesh_shape``), a single-mesh-axis
    rule per logical axis, and whether parameter sharding is restricted
    to each weight's *output* (last) dim.

    ``last_dim_only=True`` is the serving tensor-parallel plan: only
    column-parallel weight shards (wq/wk/wv/w_gate/w_up on their output
    dim, rank-1 biases), with AllGather at the transitions back to
    replicated weights — every arithmetic op then computes bit-identical
    values to the single-device graph, which is what makes greedy
    serving token-for-token reproducible across ``tp``.  Row-parallel
    cuts (AllReduce after the matmul) remain available to profiles with
    ``last_dim_only=False``; they re-round split bf16 contractions and
    so trade exactness for halved activations.
    """

    name: str
    axes: Tuple[str, ...]                 # mesh axis names, one per mesh dim
    rules: Dict[str, str]                 # logical axis -> mesh axis
    last_dim_only: bool = False
    # logical axes exempt from the last-dim restriction (e.g. 'kv_heads',
    # which tags an interior dim of the paged KV pool buffers)
    anywhere: Tuple[str, ...] = ()

    def axis_sizes(self, mesh_shape: Sequence[int]) -> Dict[str, int]:
        if len(mesh_shape) != len(self.axes):
            raise ValueError(
                f"partition profile {self.name!r} has axes {self.axes} "
                f"but mesh_shape {tuple(mesh_shape)}")
        return dict(zip(self.axes, (int(s) for s in mesh_shape)))


def _policy_pass_rules(policy: ShardingPolicy,
                       mesh_axes: Tuple[str, ...]) -> Dict[str, str]:
    """Flatten a pjit policy to the pass's one-axis-per-logical table
    (the pass shards each dim over at most one mesh axis).  Resolved
    axes outside the profile's mesh (e.g. 'pod' on a (data, model)
    mesh) are dropped, not blindly taken first."""
    out = {}
    for logical in policy.rules:
        if logical is None:
            continue
        axes = [a for a in policy.resolve(logical) if a in mesh_axes]
        if axes:
            out[logical] = axes[0]
    return out


def partition_profile(name: str) -> PartitionProfile:
    """Resolve ``CompileOptions.partition`` to a pass profile."""
    if name == "tp":
        return PartitionProfile(
            "tp", axes=("model",),
            rules={"heads": "model", "kv_heads": "model", "ffn": "model"},
            last_dim_only=True, anywhere=("kv_heads",))
    # pjit policy profiles double as shardmap partition profiles on a
    # (data, model) mesh; 'batch' resolves to the data axis
    policy = policy_for(name)  # raises KeyError on unknown names
    rules = _policy_pass_rules(policy, ("data", "model"))
    rules.setdefault("batch", "data")
    return PartitionProfile(name, axes=("data", "model"), rules=rules)


PARTITION_PROFILES: Tuple[str, ...] = (
    "tp", "default", "zero3_pod", "expert_parallel", "zero3_pod_ep",
    "expert_tp")


# ---------------------------------------------------------------------------
# mesh construction (moved from launch/mesh.py)
# ---------------------------------------------------------------------------
def make_production_mesh(*, multi_pod: bool = False):
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]):
    """Arbitrary mesh (tests use small fake-device meshes)."""
    import jax

    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None):
    """Mesh over whatever devices exist (smoke tests: 1 CPU)."""
    import jax

    n = len(jax.devices())
    mp = model_parallel or 1
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def mesh_axis_sizes(mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def data_axes(mesh) -> Tuple[str, ...]:
    """Axes that shard the batch (pod+data when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mesh_for_options(options) -> Any:
    """The mesh a shardmap/pjit compile runs on: ``options.mesh`` when
    given, else a fresh device mesh of ``options.mesh_shape`` with the
    partition profile's axis names."""
    if options.mesh is not None:
        return options.mesh
    if options.mesh_shape is None:
        return None
    import math

    import jax
    import numpy as np
    from jax.sharding import Mesh

    prof = partition_profile(options.partition or "tp")
    n = math.prod(options.mesh_shape)
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"mesh_shape {options.mesh_shape} needs {n} devices but only "
            f"{len(devs)} are attached (set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n} for a "
            f"CPU test mesh)")
    return Mesh(np.array(devs[:n]).reshape(options.mesh_shape), prof.axes)


# ---------------------------------------------------------------------------
# per-graph pjit glue (moved from launch/shardings.py)
# ---------------------------------------------------------------------------
def param_shardings(builder, mesh, policy: ShardingPolicy):
    out = []
    for name in builder.param_names():
        s = builder.params[name]
        info = ParamInfo(s.name, s.shape, s.dtype, s.logical_axes)
        out.append(policy.sharding_for(info, mesh))
    return out


def data_shardings(builder, mesh, policy: ShardingPolicy):
    out = []
    for node in builder.inputs:
        spec = builder.input_specs[node.name]
        out.append(policy.input_sharding(mesh, node.out_types[0].shape, spec))
    return out


def graph_shardings(graphs, mesh, policy: Optional[ShardingPolicy] = None):
    """(in_shardings, axis_rules) for a prefill/decode graph."""
    policy = policy or policy_for_arch(graphs.cfg.name)
    ins = data_shardings(graphs.builder, mesh, policy) + \
        param_shardings(graphs.builder, mesh, policy)
    return tuple(ins), policy.as_rules()


def train_step_shardings(ts, mesh, policy: Optional[ShardingPolicy] = None):
    """(in_shardings, out_shardings, donate_argnums, axis_rules) for a
    train-step Function: (data..., step, *params, *m, *v) ->
    (loss, *params', *m', *v')."""
    policy = policy or policy_for_arch(ts.graphs.cfg.name)
    b = ts.graphs.builder
    data = data_shardings(b, mesh, policy)
    repl = policy.replicated(mesh)
    pshard = param_shardings(b, mesh, policy)
    ins = tuple(data) + (repl,) + tuple(pshard) * 3
    outs = (repl,) + tuple(pshard) * 3
    n_data = len(data)
    donate = tuple(range(n_data + 1, n_data + 1 + 3 * len(pshard)))
    return ins, outs, donate, policy.as_rules()
