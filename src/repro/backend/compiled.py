"""CompiledFunction: the opaque executable a Backend hands back.

The paper's contract (sec. 4): a bridge asks a named backend to compile a
``Function`` and receives something it can only *call* — every optimization,
kernel-selection, and partitioning decision is sealed behind this object.
It carries the compile artifacts as metadata: the :class:`PipelineReport`
from the pass pipeline, a liveness-driven memory plan, and the IR-level
cost estimate (both computed lazily — they are diagnostics, not hot path).
"""
from __future__ import annotations

import time
from typing import Any, Callable, List, Optional

import numpy as np

from ..core.function import Function
from ..core.passes.base import PipelineReport
from .options import CompileOptions


class CompiledFunction:
    """A compiled Function: positional/named-callable, with metadata.

    ``__call__`` returns a list of numpy arrays (the stable cross-backend
    convention); ``raw`` exposes the backend-native callable (jax arrays,
    donation honored) for hot loops like the train step.
    """

    def __init__(
        self,
        fn: Function,
        call: Callable[..., List[np.ndarray]],
        *,
        backend: str,
        options: CompileOptions,
        report: PipelineReport,
        signature: str,
        raw: Optional[Callable] = None,
        lower: Optional[Callable] = None,
        memory_plan=None,
        cost=None,
        from_disk: bool = False,
    ):
        self.function = fn
        self.backend = backend
        self.options = options
        self.report = report
        self.signature = signature
        self.from_disk = from_disk  # hydrated from the persistent cache
        self._call = call
        self._raw = raw if raw is not None else call
        self._lower = lower
        # a disk hit arrives with the plan/cost already computed (they were
        # persisted alongside the graph); cold compiles stay lazy
        self._memory_plan = memory_plan
        self._cost = cost
        # NOTE: instances are shared process-wide via the backend compile
        # cache, so timing hooks are additive — setting would let one
        # caller silently unhook another's.
        self._timing_hooks: List[Callable[["CompiledFunction", float], None]] = []
        self.last_seconds: Optional[float] = None
        self.n_calls = 0

    # -- calling -------------------------------------------------------------
    def _bind(self, args, kwargs) -> List[Any]:
        params = self.function.parameters
        if not kwargs:
            bound = list(args)
        else:
            names = [p.name for p in params]
            pos = {n: i for i, n in enumerate(names)}
            bound: List[Any] = [_MISSING] * len(params)
            for i, a in enumerate(args):
                if i >= len(params):
                    break  # length error reported below
                bound[i] = a
            for k, v in kwargs.items():
                if k not in pos:
                    raise TypeError(
                        f"{self.function.name}: unknown parameter {k!r}; "
                        f"parameters are {names}")
                if bound[pos[k]] is not _MISSING:
                    raise TypeError(
                        f"{self.function.name}: parameter {k!r} given both "
                        f"positionally and by name")
                bound[pos[k]] = v
            missing = [n for n, b in zip(names, bound) if b is _MISSING]
            if missing:
                raise TypeError(
                    f"{self.function.name}: missing parameters {missing}")
        if len(bound) != len(params):
            raise TypeError(
                f"{self.function.name} expects {len(params)} args, "
                f"got {len(bound)}")
        return bound

    def __call__(self, *args, **kwargs) -> List[np.ndarray]:
        bound = self._bind(args, kwargs)
        t0 = time.perf_counter()
        out = self._call(*bound)
        dt = time.perf_counter() - t0
        self.last_seconds = dt
        self.n_calls += 1
        for hook in self._timing_hooks:
            hook(self, dt)
        return out

    def add_timing_hook(
            self, hook: Callable[["CompiledFunction", float], None]) -> None:
        """Register a per-call hook ``hook(compiled, seconds)``."""
        self._timing_hooks.append(hook)

    def remove_timing_hook(self, hook: Callable) -> None:
        self._timing_hooks.remove(hook)

    @property
    def raw(self) -> Callable:
        """Backend-native callable (jax arrays on jax; positional only)."""
        return self._raw

    def lower(self, *args):
        """AOT-lower (jax): accepts ShapeDtypeStructs, returns a Lowered."""
        if self._lower is None:
            raise NotImplementedError(
                f"backend {self.backend!r} does not support lower()")
        return self._lower(*args)

    def warmup(self) -> "CompiledFunction":
        """Trigger backend compilation with zero-filled inputs.

        Donation-safe: the zero buffers are freshly allocated here on
        every call — never the caller's arrays — so warming an executable
        compiled with ``donate_argnums`` can only invalidate its own
        temporaries.  The warmup goes through ``__call__`` (numpy
        convention), which device-puts fresh backend buffers per call, so
        a warmed donated executable serves subsequent real calls
        normally; serving engines may warm before entering a
        donation-honoring ``.raw`` hot loop."""
        self(*[np.zeros(t.shape, t.dtype) for t in self.function.in_types])
        return self

    # -- metadata ------------------------------------------------------------
    @property
    def memory_plan(self):
        """Liveness-driven arena plan for the optimized graph (lazy)."""
        if self._memory_plan is None:
            from ..core.passes import plan_memory
            self._memory_plan = plan_memory(self.function)
        return self._memory_plan

    @property
    def cost(self):
        """IR-level FLOPs/bytes estimate for the optimized graph (lazy)."""
        if self._cost is None:
            from ..core.cost import function_cost
            impl = self.options.attn_impl
            self._cost = function_cost(
                self.function,
                attn_impl=impl if impl in ("naive", "chunked") else "chunked")
        return self._cost

    def __repr__(self) -> str:
        return (f"CompiledFunction({self.function.name!r}, "
                f"backend={self.backend!r}, passes={len(self.report.stats)}, "
                f"nodes={self.report.nodes_after})")


_MISSING = object()
