"""The unified compilation API (paper sec. 4, redesigned).

    from repro.backend import Backend, CompileOptions

    be = Backend.create("jax")                     # or "interpreter"
    cf = be.compile(fn, CompileOptions(level="O2"))
    outs = cf(*arrays)            # positional, or cf(x=..., w=...)
    cf.report.summary()           # the pass-pipeline report
    cf.memory_plan, cf.cost       # arena plan + FLOPs/bytes estimate
    be.cache_stats()              # compile-cache hits/misses

Repeated ``compile`` calls with a structurally-identical Function and equal
options are cache hits (keyed on ``Function.signature()`` + the options).
The legacy ``repro.transformers.get_transformer`` path is a deprecated
shim over this module and will be removed after one release.
"""
from .base import (Backend, CacheStats, available_backends,  # noqa: F401
                   register_backend)
from .compiled import CompiledFunction  # noqa: F401
from .diskcache import DiskCompileCache  # noqa: F401
from .options import CompileOptions, OptionsError  # noqa: F401
from . import interpreter as _interpreter  # noqa: F401  (registers itself)

try:  # jax backend registers on import; interpreter works without jax
    from . import jax_backend as _jax_backend  # noqa: F401
except ImportError:  # pragma: no cover
    pass
