"""Attention autotuner: sweep attn_impl/attn_chunk/use_pallas, remember.

TVM-style "record the schedule choice" scaled to this repo's knob space:
``Backend.compile(fn, CompileOptions(autotune=True))`` calls
:func:`resolve`, which returns *concrete* options — from a persisted
tuning record when one exists for this (backend, shape-signature,
versions), else by compiling and timing a small candidate grid and
persisting the winner into the disk cache (``<cache_dir>/autotune/``).
The second process to compile the same graph performs zero sweep timings.

A sweep always times the statically-resolved default as candidate 0, so
the recorded winner is by construction no slower than the default on the
machine that tuned it.  Records are keyed on jax+repro versions like
compile entries: a toolchain bump re-tunes instead of trusting stale
timings.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, List, Optional

import numpy as np

from ..core.function import Function
from . import diskcache
from .options import CompileOptions, _stable_token, _UNSTABLE

SCHEMA = "repro-autotune-v1"
SWEEP_REPS = 3          # timed calls per candidate (after one warmup call)
CHUNK_CANDIDATES = (256, 1024)

# the knobs the tuner owns; everything else is identity (part of the key)
TUNED_FIELDS = ("attn_impl", "attn_chunk", "use_pallas")

# record schema, shared with scripts/bench_to_json.py --check validation
RECORD_REQUIRED_KEYS = ("format", "schema", "backend", "signature",
                        "candidates", "winner", "versions")
CANDIDATE_REQUIRED_KEYS = TUNED_FIELDS + ("ms",)


@dataclasses.dataclass
class SweepResult:
    key: Optional[str]            # record key (None: options not stable)
    candidates: List[Dict]        # [{attn_impl, attn_chunk, use_pallas, ms}]
    winner: Dict                  # the fastest candidate's knobs
    swept: bool                   # False when a record was reused


def tune_key(backend, fn: Function, options: CompileOptions,
             signature: Optional[str] = None) -> Optional[str]:
    """Record key: everything that invalidates a timing, minus the tuned
    knobs themselves (records must be found regardless of the requested
    starting point)."""
    toks = []
    for f in dataclasses.fields(options):
        if f.name in TUNED_FIELDS or f.name in CompileOptions._NON_IDENTITY:
            continue
        t = _stable_token(getattr(options, f.name))
        if t is _UNSTABLE:
            return None
        toks.append((f.name, t))
    opts_tok = _stable_token(tuple(sorted(backend.backend_opts.items())))
    if opts_tok is _UNSTABLE:
        return None
    doc = (SCHEMA, backend.name, signature or fn.signature(), tuple(toks),
           opts_tok, tuple(sorted(diskcache._versions().items())),
           options.level or backend.default_level)
    return hashlib.sha256(repr(doc).encode()).hexdigest()


def has_attention(fn: Function) -> bool:
    """True if the graph executes any Attention node — including inside
    nested Functions (Scan bodies carry the per-layer attention)."""
    for n in fn.nodes():
        if n.op == "Attention":
            return True
        for v in n.attrs.values():
            if isinstance(v, Function) and has_attention(v):
                return True
            if isinstance(v, (tuple, list)) and any(
                    isinstance(x, Function) and has_attention(x) for x in v):
                return True
    return False


def candidate_grid(options: CompileOptions) -> List[Dict]:
    """The sweep grid.  Candidate 0 is always the request as-given (the
    static default), so the winner can never regress it."""
    seen = set()
    grid: List[Dict] = []

    def add(impl: str, chunk: int, pallas: bool):
        key = (impl, chunk, pallas)
        if key not in seen:
            seen.add(key)
            grid.append({"attn_impl": impl, "attn_chunk": chunk,
                         "use_pallas": pallas})

    add(options.attn_impl, options.attn_chunk, options.use_pallas)
    add("naive", options.attn_chunk, options.use_pallas)
    for c in sorted({options.attn_chunk, *CHUNK_CANDIDATES}):
        add("chunked", c, options.use_pallas)
    # one use_pallas flip of the request: times the kernel-vs-XLA choice
    # without crossing it with every impl
    add(options.attn_impl, options.attn_chunk, not options.use_pallas)
    return grid


def resolve(backend, fn: Function,
            options: CompileOptions) -> CompileOptions:
    """Concrete options for ``fn``: record lookup, else sweep + persist.

    Called by ``Backend.compile`` when ``options.autotune`` is set; the
    returned options always have ``autotune=False`` (they are the
    resolution, not another request)."""
    static = options.replace(autotune=False)
    if not has_attention(fn):
        return static  # nothing to tune
    sig = fn.signature()
    key = tune_key(backend, fn, options, signature=sig)
    # Options carrying opaque objects (mesh/shardings) have key=None and
    # can never persist — but a repeated compile in one process must still
    # not re-pay the sweep, so everything memoizes in-process too.
    mem_key = key if key is not None else (
        "mem", sig, static.cache_key(),
        options.level or backend.default_level)
    rec = _load_record(backend, options, key, mem_key)
    if rec is not None:
        backend.autotune_hits += 1
        return static.replace(**_knobs(rec["winner"]))
    result = sweep(backend, fn, static, key=key)
    backend.autotune_sweeps += 1
    _store_record(backend, fn, options, result, mem_key)
    _drop_loser_entries(backend, fn, static, result, signature=sig)
    return static.replace(**_knobs(result.winner))


def sweep(backend, fn: Function, static: CompileOptions,
          key: Optional[str] = None, reps: int = SWEEP_REPS) -> SweepResult:
    """Compile + time every candidate; fastest mean wall time wins.

    Candidates that fail to compile or run (e.g. a chunk size the shapes
    reject) are skipped — candidate 0 (the static default) always runs, so
    the sweep cannot come back empty."""
    args = [np.zeros(t.shape, t.dtype) for t in fn.in_types]
    timed: List[Dict] = []
    for cand in candidate_grid(static):
        try:
            cf = backend.compile(fn, static.replace(**cand))
            cf(*args)  # warmup: XLA compile + first dispatch
            t0 = time.perf_counter()
            for _ in range(reps):
                cf(*args)  # numpy convention: host round-trip syncs
            ms = (time.perf_counter() - t0) / reps * 1e3
        except Exception:
            if not timed:
                raise  # the static default must be runnable
            continue
        timed.append({**cand, "ms": ms})
    winner = min(timed, key=lambda c: c["ms"])
    return SweepResult(key=key, candidates=timed, winner=_knobs(winner),
                       swept=True)


def _knobs(doc: Dict) -> Dict:
    return {k: doc[k] for k in TUNED_FIELDS}


def record_doc(backend, fn: Function, result: SweepResult) -> Dict:
    return {
        "format": diskcache.ENTRY_FORMAT,
        "schema": SCHEMA,
        "backend": backend.name,
        "signature": fn.signature(),
        "key": result.key,
        "candidates": result.candidates,
        "winner": result.winner,
        "versions": diskcache._versions(),
    }


def validate_record(rec: Dict) -> List[str]:
    """Schema errors for one tuning record ([] = valid).  Shared with
    ``scripts/bench_to_json.py --check``."""
    errors = []
    if not isinstance(rec, dict):
        return [f"record must be an object, got {type(rec).__name__}"]
    for k in RECORD_REQUIRED_KEYS:
        if k not in rec:
            errors.append(f"missing key {k!r}")
    if rec.get("schema") not in (None, SCHEMA):
        errors.append(f"schema {rec['schema']!r} != {SCHEMA!r}")
    cands = rec.get("candidates")
    if cands is not None:
        if not isinstance(cands, list) or not cands:
            errors.append("candidates must be a non-empty list")
        else:
            for i, c in enumerate(cands):
                if not isinstance(c, dict):
                    errors.append(f"candidates[{i}] must be an object")
                    continue
                for k in CANDIDATE_REQUIRED_KEYS:
                    if k not in c:
                        errors.append(f"candidates[{i}] missing {k!r}")
                ms = c.get("ms")
                if ms is not None and (
                        not isinstance(ms, (int, float)) or ms < 0):
                    errors.append(f"candidates[{i}].ms not a time: {ms!r}")
    win = rec.get("winner")
    if win is not None:
        if not isinstance(win, dict):
            errors.append("winner must be an object")
        else:
            for k in TUNED_FIELDS:
                if k not in win:
                    errors.append(f"winner missing {k!r}")
    return errors


def _drop_loser_entries(backend, fn: Function, static: CompileOptions,
                        result: SweepResult, signature: str) -> None:
    """Remove the losing candidates' disk entries after a sweep.

    Sweep compiles go through the normal ``Backend.compile`` path, so
    every candidate persisted a full entry — but only the winner's is ever
    addressed again; the rest would squat on LRU budget until evicted."""
    disk = backend._disk_for(static)
    if disk is None:
        return
    level = static.level or backend.default_level
    params = tuple(p.name for p in fn.parameters)
    for cand in result.candidates:
        knobs = _knobs(cand)
        if knobs == result.winner:
            continue
        dkey = diskcache.entry_key(signature, params, level,
                                   static.replace(**knobs), backend.name,
                                   backend.backend_opts)
        if dkey is not None:
            disk._remove(disk._entry_path(dkey))


# -- persistence --------------------------------------------------------------
def _load_record(backend, options: CompileOptions, key: Optional[str],
                 mem_key) -> Optional[Dict]:
    rec = backend._autotune_mem.get(mem_key)
    if rec is not None:
        return rec
    if key is None:
        return None
    disk = backend._disk_for(options)
    if disk is not None:
        rec = disk.load_tuning(key)
        if rec is not None and not validate_record(rec):
            return rec
    return None


def _store_record(backend, fn: Function, options: CompileOptions,
                  result: SweepResult, mem_key) -> None:
    rec = record_doc(backend, fn, result)
    backend._autotune_mem[mem_key] = rec
    if result.key is not None:
        disk = backend._disk_for(options)
        if disk is not None:
            disk.store_tuning(result.key, rec)
