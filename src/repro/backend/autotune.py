"""Kernel autotuner: sweep attention/matmul/fusion knobs, remember.

TVM-style "record the schedule choice" scaled to this repo's knob space:
``Backend.compile(fn, CompileOptions(autotune=True))`` calls
:func:`resolve`, which returns *concrete* options — from a persisted
tuning record when one exists for this (backend, shape-signature,
versions), else by compiling and timing a small candidate grid and
persisting the winner into the disk cache (``<cache_dir>/autotune/``).
The second process to compile the same graph performs zero sweep timings.

The grid is *family-gated* so sweeps stay small: attention knobs
(``attn_impl``/``attn_chunk``/``use_pallas``) are swept only when the
graph executes an Attention node; matmul tile shapes
(``mm_bm``/``mm_bn``/``mm_bk``, shared by the matmul / SwiGLU /
NormMatmul Pallas kernels) only when ``use_pallas`` is requested; and
per-compound fusion on/off flips (``fuse_swiglu``/``fuse_norm_matmul``/
``fuse_rotary_qkv``) only when the resolved level is O2 — the only level
where :class:`FuseCompounds` runs, so flipping them anywhere else would
time identical executables.

A sweep always times the statically-resolved default as candidate 0, so
the recorded winner is by construction no slower than the default on the
machine that tuned it.  Records are keyed on jax+repro versions like
compile entries: a toolchain bump re-tunes instead of trusting stale
timings.  v1 (attention-only) records remain *valid* for schema checks —
CI caches carry them across upgrades — but never resolve a v2 request:
the schema participates in the record key, so v2 re-tunes.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Dict, FrozenSet, List, Optional

import numpy as np

from ..core.function import Function
from . import diskcache
from .options import CompileOptions, _stable_token, _UNSTABLE

SCHEMA_V1 = "repro-autotune-v1"
SCHEMA = "repro-autotune-v2"
ACCEPTED_SCHEMAS = (SCHEMA, SCHEMA_V1)
SWEEP_REPS = 3          # timed calls per candidate (after one warmup call)
CHUNK_CANDIDATES = (256, 1024)
# matmul-family tile shapes (bm, bn, bk); swept when use_pallas is on
MM_TILE_CANDIDATES = ((128, 128, 128), (256, 256, 256),
                      (256, 256, 512), (512, 512, 512))

# the knobs the tuner owns; everything else is identity (part of the key)
TUNED_FIELDS_V1 = ("attn_impl", "attn_chunk", "use_pallas")
TUNED_FIELDS = TUNED_FIELDS_V1 + (
    "mm_bm", "mm_bn", "mm_bk",
    "fuse_swiglu", "fuse_norm_matmul", "fuse_rotary_qkv")

# record schema, shared with scripts/bench_to_json.py --check validation
RECORD_REQUIRED_KEYS = ("format", "schema", "backend", "signature",
                        "candidates", "winner", "versions")
CANDIDATE_REQUIRED_KEYS = TUNED_FIELDS + ("ms",)


@dataclasses.dataclass
class SweepResult:
    key: Optional[str]            # record key (None: options not stable)
    candidates: List[Dict]        # [{attn_impl, attn_chunk, use_pallas, ms}]
    winner: Dict                  # the fastest candidate's knobs
    swept: bool                   # False when a record was reused


def tune_key(backend, fn: Function, options: CompileOptions,
             signature: Optional[str] = None) -> Optional[str]:
    """Record key: everything that invalidates a timing, minus the tuned
    knobs themselves (records must be found regardless of the requested
    starting point)."""
    toks = []
    for f in dataclasses.fields(options):
        if f.name in TUNED_FIELDS or f.name in CompileOptions._NON_IDENTITY:
            continue
        t = _stable_token(getattr(options, f.name))
        if t is _UNSTABLE:
            return None
        toks.append((f.name, t))
    opts_tok = _stable_token(tuple(sorted(backend.backend_opts.items())))
    if opts_tok is _UNSTABLE:
        return None
    doc = (SCHEMA, backend.name, signature or fn.signature(), tuple(toks),
           opts_tok, tuple(sorted(diskcache._versions().items())),
           options.level or backend.default_level)
    return hashlib.sha256(repr(doc).encode()).hexdigest()


def _collect_ops(fn: Function, acc: set) -> set:
    for n in fn.nodes():
        acc.add(n.op)
        for v in n.attrs.values():
            if isinstance(v, Function):
                _collect_ops(v, acc)
            elif isinstance(v, (tuple, list)):
                for x in v:
                    if isinstance(x, Function):
                        _collect_ops(x, acc)
    return acc


def has_attention(fn: Function) -> bool:
    """True if the graph executes any Attention node — including inside
    nested Functions (Scan bodies carry the per-layer attention)."""
    return "Attention" in _collect_ops(fn, set())


# ops that route through the matmul-family Pallas kernels (tile shapes)
# and that FuseCompounds can create or leave unfused (fusion flips)
_MM_FAMILY_OPS = frozenset(
    {"DotGeneral", "SwiGLU", "NormMatmul", "RotaryQKV"})


def tunable_families(fn: Function, options: CompileOptions,
                     backend=None) -> FrozenSet[str]:
    """Which knob families a sweep of ``fn`` under ``options`` can
    actually exercise.  Empty = nothing to tune, skip the sweep."""
    ops = _collect_ops(fn, set())
    fams = set()
    if "Attention" in ops:
        fams.add("attention")
    has_mm = bool(ops & _MM_FAMILY_OPS)
    if has_mm and options.use_pallas:
        fams.add("matmul")
    level = options.level or (backend.default_level if backend is not None
                              else "O1")
    if has_mm and level == "O2":
        fams.add("fusion")
    return frozenset(fams)


def candidate_grid(options: CompileOptions,
                   families: FrozenSet[str] = frozenset({"attention"})
                   ) -> List[Dict]:
    """The sweep grid.  Candidate 0 is always the request as-given (the
    static default), so the winner can never regress it.  Each family
    varies its own knobs against the request — no cross products, so the
    grid stays linear in the number of families."""
    seen = set()
    grid: List[Dict] = []
    base = {k: getattr(options, k) for k in TUNED_FIELDS}

    def add(**over):
        cand = dict(base)
        cand.update(over)
        key = tuple(cand[k] for k in TUNED_FIELDS)
        if key not in seen:
            seen.add(key)
            grid.append(cand)

    add()  # candidate 0: the request as-given
    if "attention" in families:
        add(attn_impl="naive")
        for c in sorted({options.attn_chunk, *CHUNK_CANDIDATES}):
            add(attn_impl="chunked", attn_chunk=c)
        # one use_pallas flip of the request: times the kernel-vs-XLA
        # choice without crossing it with every impl
        add(use_pallas=not options.use_pallas)
    if "matmul" in families:
        for bm, bn, bk in MM_TILE_CANDIDATES:
            add(mm_bm=bm, mm_bn=bn, mm_bk=bk)
        if options.use_pallas and "attention" not in families:
            add(use_pallas=False)  # XLA escape for matmul-only graphs
    if "fusion" in families:
        # flip each compound off one at a time, plus the all-unfused
        # baseline the E14 microbenchmarks compare against
        add(fuse_swiglu=not options.fuse_swiglu)
        add(fuse_norm_matmul=not options.fuse_norm_matmul)
        add(fuse_rotary_qkv=not options.fuse_rotary_qkv)
        add(fuse_swiglu=False, fuse_norm_matmul=False,
            fuse_rotary_qkv=False)
    return grid


def resolve(backend, fn: Function,
            options: CompileOptions) -> CompileOptions:
    """Concrete options for ``fn``: record lookup, else sweep + persist.

    Called by ``Backend.compile`` when ``options.autotune`` is set; the
    returned options always have ``autotune=False`` (they are the
    resolution, not another request)."""
    static = options.replace(autotune=False)
    families = tunable_families(fn, options, backend)
    if not families:
        return static  # nothing to tune
    sig = fn.signature()
    key = tune_key(backend, fn, options, signature=sig)
    # Options carrying opaque objects (mesh/shardings) have key=None and
    # can never persist — but a repeated compile in one process must still
    # not re-pay the sweep, so everything memoizes in-process too.
    mem_key = key if key is not None else (
        "mem", sig, static.cache_key(),
        options.level or backend.default_level)
    rec = _load_record(backend, options, key, mem_key)
    if rec is not None:
        try:
            resolved = static.replace(**_knobs(rec["winner"]))
        except Exception:
            # a schema-valid record can still carry garbage winner
            # values (torn write racing store_tuning, hand edits);
            # evict it and re-sweep instead of failing the compile
            _evict_record(backend, options, key, mem_key)
        else:
            backend.autotune_hits += 1
            return resolved
    result = sweep(backend, fn, static, key=key, families=families)
    backend.autotune_sweeps += 1
    _store_record(backend, fn, options, result, mem_key)
    _drop_loser_entries(backend, fn, static, result, signature=sig)
    return static.replace(**_knobs(result.winner))


def sweep(backend, fn: Function, static: CompileOptions,
          key: Optional[str] = None, reps: int = SWEEP_REPS,
          families: Optional[FrozenSet[str]] = None) -> SweepResult:
    """Compile + time every candidate; fastest mean wall time wins.

    Candidates that fail to compile or run (e.g. a chunk size the shapes
    reject) are skipped — candidate 0 (the static default) always runs, so
    the sweep cannot come back empty."""
    if families is None:
        families = tunable_families(fn, static, backend) or \
            frozenset({"attention"})
    args = [np.zeros(t.shape, t.dtype) for t in fn.in_types]
    timed: List[Dict] = []
    for cand in candidate_grid(static, families):
        try:
            cf = backend.compile(fn, static.replace(**cand))
            cf(*args)  # warmup: XLA compile + first dispatch
            t0 = time.perf_counter()
            for _ in range(reps):
                cf(*args)  # numpy convention: host round-trip syncs
            ms = (time.perf_counter() - t0) / reps * 1e3
        except Exception:
            if not timed:
                raise  # the static default must be runnable
            continue
        timed.append({**cand, "ms": ms})
    winner = min(timed, key=lambda c: c["ms"])
    return SweepResult(key=key, candidates=timed, winner=_knobs(winner),
                       swept=True)


def _knobs(doc: Dict) -> Dict:
    return {k: doc[k] for k in TUNED_FIELDS}


def record_doc(backend, fn: Function, result: SweepResult) -> Dict:
    return {
        "format": diskcache.ENTRY_FORMAT,
        "schema": SCHEMA,
        "backend": backend.name,
        "signature": fn.signature(),
        "key": result.key,
        "candidates": result.candidates,
        "winner": result.winner,
        "versions": diskcache._versions(),
    }


def validate_record(rec: Dict) -> List[str]:
    """Schema errors for one tuning record ([] = valid).  Shared with
    ``scripts/bench_to_json.py --check``."""
    errors = []
    if not isinstance(rec, dict):
        return [f"record must be an object, got {type(rec).__name__}"]
    for k in RECORD_REQUIRED_KEYS:
        if k not in rec:
            errors.append(f"missing key {k!r}")
    schema = rec.get("schema")
    if schema not in (None,) + ACCEPTED_SCHEMAS:
        errors.append(f"schema {rec['schema']!r} not in {ACCEPTED_SCHEMAS!r}")
    # v1 records (stale CI caches) validate against the v1 knob set; they
    # never *resolve* a v2 request — the schema is part of the record key
    fields = TUNED_FIELDS_V1 if schema == SCHEMA_V1 else TUNED_FIELDS
    cand_required = fields + ("ms",)
    cands = rec.get("candidates")
    if cands is not None:
        if not isinstance(cands, list) or not cands:
            errors.append("candidates must be a non-empty list")
        else:
            for i, c in enumerate(cands):
                if not isinstance(c, dict):
                    errors.append(f"candidates[{i}] must be an object")
                    continue
                for k in cand_required:
                    if k not in c:
                        errors.append(f"candidates[{i}] missing {k!r}")
                ms = c.get("ms")
                if ms is not None and (
                        not isinstance(ms, (int, float)) or ms < 0):
                    errors.append(f"candidates[{i}].ms not a time: {ms!r}")
    win = rec.get("winner")
    if win is not None:
        if not isinstance(win, dict):
            errors.append("winner must be an object")
        else:
            for k in fields:
                if k not in win:
                    errors.append(f"winner missing {k!r}")
    return errors


def _drop_loser_entries(backend, fn: Function, static: CompileOptions,
                        result: SweepResult, signature: str) -> None:
    """Remove the losing candidates' disk entries after a sweep.

    Sweep compiles go through the normal ``Backend.compile`` path, so
    every candidate persisted a full entry — but only the winner's is ever
    addressed again; the rest would squat on LRU budget until evicted."""
    disk = backend._disk_for(static)
    if disk is None:
        return
    level = static.level or backend.default_level
    params = tuple(p.name for p in fn.parameters)
    for cand in result.candidates:
        knobs = _knobs(cand)
        if knobs == result.winner:
            continue
        dkey = diskcache.entry_key(signature, params, level,
                                   static.replace(**knobs), backend.name,
                                   backend.backend_opts)
        if dkey is not None:
            disk._remove(disk._entry_path(dkey))


# -- persistence --------------------------------------------------------------
def _load_record(backend, options: CompileOptions, key: Optional[str],
                 mem_key) -> Optional[Dict]:
    rec = backend._autotune_mem.get(mem_key)
    if rec is not None:
        return rec
    if key is None:
        return None
    disk = backend._disk_for(options)
    if disk is not None:
        rec = disk.load_tuning(key)
        if rec is not None:
            if not validate_record(rec):
                return rec
            # parses as JSON but fails the schema (partial write that
            # still decodes, wrong-version hand edit): evict so it
            # stops shadowing the re-sweep forever
            disk.remove_tuning(key)
    return None


def _evict_record(backend, options: CompileOptions, key: Optional[str],
                  mem_key) -> None:
    """Drop a record that failed to resolve, everywhere it is cached."""
    backend._autotune_mem.pop(mem_key, None)
    if key is not None:
        disk = backend._disk_for(options)
        if disk is not None:
            disk.remove_tuning(key)


def _store_record(backend, fn: Function, options: CompileOptions,
                  result: SweepResult, mem_key) -> None:
    rec = record_doc(backend, fn, result)
    backend._autotune_mem[mem_key] = rec
    if result.key is not None:
        disk = backend._disk_for(options)
        if disk is not None:
            disk.store_tuning(result.key, rec)
