"""DiskCompileCache: the persistence layer under the in-memory compile cache.

A compile whose options are process-stable (no opaque meshes/shardings/
plans — see ``CompileOptions.stable_token``) is written to disk keyed on
the canonical graph signature + parameter names + resolved level + options
+ backend name/opts + the jax and repro versions, so a *cold process* that
rebuilds a structurally-identical graph skips the pass pipeline entirely:
the entry stores the serialized *optimized* graph (``core.serialize``),
the :class:`PipelineReport`, the memory-plan totals, the cost estimate,
and — where the backend supports it — an AOT-serialized executable
(``jax.export``).  A version bump of jax or repro changes every key, which
is the invalidation story: stale entries stop being addressed and age out
via eviction.

Robustness contract (tested in ``tests/test_diskcache.py``):

  * writes go to a temp file in the same directory and are published with
    ``os.replace`` — concurrent processes racing on one key cannot clobber
    each other or expose a torn entry;
  * a corrupt/truncated/alien entry is *skipped and evicted*, never
    allowed to fail a compile;
  * total entry bytes are kept under ``budget_bytes`` by LRU eviction
    (hits refresh an entry's mtime, eviction removes oldest-mtime first).
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import json
import os
import tempfile
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core import serialize
from ..core.function import Function
from ..core.passes.base import PipelineReport

ENTRY_FORMAT = 1
ENTRY_SUFFIX = ".entry.json"
TUNE_DIR = "autotune"

# defaults, overridable per-options and by environment (CI convenience:
# exporting REPRO_CACHE_DIR turns the cache on for every compile in the
# process without touching call sites)
ENV_DIR = "REPRO_CACHE_DIR"
ENV_BUDGET = "REPRO_CACHE_BUDGET_BYTES"
DEFAULT_BUDGET_BYTES = 1 << 30  # 1 GiB


def resolve_dir(options) -> Optional[str]:
    """The cache root for ``options``: explicit field, else environment."""
    root = options.cache_dir
    if root is None:
        root = os.environ.get(ENV_DIR) or None
    # a '~/...' from a config file or .env never saw the shell — expanding
    # here keeps it from becoming a literal './~' directory
    return os.path.expanduser(root) if root else None


def resolve_budget(options=None) -> int:
    """Byte budget: explicit option, else environment, else the default
    (options=None resolves environment/default only — cache_tool.py)."""
    if options is not None and options.cache_budget_bytes is not None:
        return options.cache_budget_bytes
    env = os.environ.get(ENV_BUDGET)
    if env:
        try:
            return max(int(env), 1)
        except ValueError:
            pass
    return DEFAULT_BUDGET_BYTES


def _versions() -> Dict[str, str]:
    import repro
    vs = {"repro": repro.__version__}
    try:
        import jax
        vs["jax"] = jax.__version__
    except Exception:  # pragma: no cover - jax is baked into the image
        vs["jax"] = "none"
    return vs


def entry_key(signature: str, param_names: Tuple[str, ...], level: str,
              options, backend_name: str,
              backend_opts: Optional[Dict] = None) -> Optional[str]:
    """Hex digest addressing one executable on disk, or None if the
    options aren't process-stable (opaque mesh/sharding/plan objects)."""
    tok = options.stable_token()
    if tok is None:
        return None
    from .options import _stable_token, _UNSTABLE
    opts_tok = _stable_token(tuple(sorted((backend_opts or {}).items())))
    if opts_tok is _UNSTABLE:
        return None
    doc = ("repro-diskcache-v%d" % ENTRY_FORMAT, signature,
           tuple(param_names), level, backend_name, opts_tok, tok,
           tuple(sorted(_versions().items())))
    return hashlib.sha256(repr(doc).encode()).hexdigest()


@dataclasses.dataclass
class DiskStats:
    entries: int
    total_bytes: int
    budget_bytes: int
    hits: int
    misses: int
    evictions: int


class DiskCompileCache:
    """One on-disk cache root; safe for many processes to share."""

    def __init__(self, root: str, budget_bytes: int = DEFAULT_BUDGET_BYTES):
        self.root = root
        self.budget_bytes = int(budget_bytes)
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        os.makedirs(root, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _entry_path(self, key: str) -> str:
        return os.path.join(self.root, key + ENTRY_SUFFIX)

    def entry_paths(self) -> List[str]:
        try:
            names = os.listdir(self.root)
        except OSError:
            return []
        return sorted(os.path.join(self.root, n) for n in names
                      if n.endswith(ENTRY_SUFFIX))

    # -- load ----------------------------------------------------------------
    def load(self, key: str) -> Optional[Dict]:
        """The decoded entry for ``key``, or None (miss / corrupt).

        Corrupt entries are evicted on the spot: a broken file must never
        be able to fail a compile, and leaving it would make every future
        lookup of its key re-pay the failed parse."""
        path = self._entry_path(key)
        try:
            with open(path) as fh:
                entry = json.load(fh)
            if entry.get("format") != ENTRY_FORMAT:
                raise ValueError(f"format {entry.get('format')!r}")
            if entry.get("serialize_format") != serialize.FORMAT_VERSION:
                raise ValueError(
                    f"serialize format {entry.get('serialize_format')!r}")
            # decode up front so a truncated graph doc is caught *here*
            fn = serialize.from_doc(entry["function"])
            report = PipelineReport(
                stats=[(name, dict(st)) for name, st in entry["report"]["stats"]],
                nodes_before=int(entry["report"]["nodes_before"]),
                nodes_after=int(entry["report"]["nodes_after"]),
                seconds=float(entry["report"]["seconds"]))
            entry["function"] = fn
            entry["report"] = report
            if entry.get("executable"):
                entry["executable"] = base64.b64decode(entry["executable"])
        except FileNotFoundError:
            self.misses += 1
            return None
        except Exception:
            self._remove(path)
            self.evictions += 1
            self.misses += 1
            return None
        self.hits += 1
        try:
            os.utime(path)  # LRU touch: a hit is a use
        except OSError:
            pass
        return entry

    # -- store ---------------------------------------------------------------
    def store(self, key: str, *, fn: Function, report: PipelineReport,
              level: str, backend_name: str, options,
              memory_plan=None, cost=None,
              executable: Optional[bytes] = None) -> None:
        """Serialize one compiled artifact; best-effort (persistence
        failures — I/O, an unserializable graph attr — are the caller's
        compile succeeding without persistence, not failing)."""
        try:
            self._store(key, fn=fn, report=report, level=level,
                        backend_name=backend_name, options=options,
                        memory_plan=memory_plan, cost=cost,
                        executable=executable)
        except Exception:
            return
        self.evict(self.budget_bytes)

    def _store(self, key: str, *, fn: Function, report: PipelineReport,
               level: str, backend_name: str, options,
               memory_plan=None, cost=None,
               executable: Optional[bytes] = None) -> None:
        entry = {
            "format": ENTRY_FORMAT,
            "key": key,
            "backend": backend_name,
            "level": level,
            "param_names": [p.name for p in fn.parameters],
            "options": _options_doc(options),
            "versions": _versions(),
            "serialize_format": serialize.FORMAT_VERSION,
            "function": serialize.to_doc(fn),
            "report": {
                "stats": [[name, st] for name, st in report.stats],
                "nodes_before": report.nodes_before,
                "nodes_after": report.nodes_after,
                "seconds": report.seconds,
            },
            "memory_plan": None if memory_plan is None else {
                "arena_bytes": memory_plan.arena_bytes,
                "naive_bytes": memory_plan.naive_bytes,
                "peak_live_bytes": memory_plan.peak_live_bytes,
                "io_bytes": memory_plan.io_bytes,
            },
            "cost": None if cost is None else {
                "flops": cost.flops,
                "bytes": cost.bytes,
                "by_op": cost.by_op,
            },
            "executable": (base64.b64encode(executable).decode()
                           if executable else None),
        }
        self._atomic_write(self._entry_path(key),
                           json.dumps(entry, sort_keys=True))

    def _atomic_write(self, path: str, text: str) -> None:
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as fh:
                fh.write(text)
            os.replace(tmp, path)
        except BaseException:
            self._remove(tmp)
            raise

    # -- eviction ------------------------------------------------------------
    #: a .tmp older than this is an orphan from a killed writer, not a
    #: write in progress — os.replace publishes within milliseconds
    STALE_TMP_SECONDS = 3600

    def _reap_stale_tmp(self) -> None:
        """Remove orphaned temp files (a writer killed between mkstemp and
        os.replace leaves one behind; entry_paths/stats never see them, so
        without this they'd accumulate invisibly forever)."""
        cutoff = time.time() - self.STALE_TMP_SECONDS
        for d in (self.root, os.path.join(self.root, TUNE_DIR)):
            try:
                names = os.listdir(d)
            except OSError:
                continue
            for n in names:
                if not n.endswith(".tmp"):
                    continue
                p = os.path.join(d, n)
                try:
                    if os.stat(p).st_mtime < cutoff:
                        self._remove(p)
                except OSError:
                    pass

    def evict(self, budget_bytes: Optional[int] = None) -> int:
        """Delete oldest-mtime entries until total size <= budget.

        Returns the number of entries removed."""
        self._reap_stale_tmp()
        budget = self.budget_bytes if budget_bytes is None else budget_bytes
        infos = []
        for p in self.entry_paths():
            try:
                st = os.stat(p)
            except OSError:
                continue
            infos.append((st.st_mtime, st.st_size, p))
        total = sum(sz for _, sz, _ in infos)
        removed = 0
        for _, sz, p in sorted(infos):
            if total <= budget:
                break
            if self._remove(p):
                total -= sz
                removed += 1
                self.evictions += 1
        return removed

    def clear(self) -> int:
        n = 0
        for p in self.entry_paths():
            n += self._remove(p)
        tdir = os.path.join(self.root, TUNE_DIR)
        if os.path.isdir(tdir):
            for name in os.listdir(tdir):
                self._remove(os.path.join(tdir, name))
        return n

    @staticmethod
    def _remove(path: str) -> bool:
        try:
            os.remove(path)
            return True
        except OSError:
            return False

    # -- introspection -------------------------------------------------------
    def stats(self) -> DiskStats:
        sizes = []
        for p in self.entry_paths():
            try:
                sizes.append(os.stat(p).st_size)
            except OSError:
                pass
        return DiskStats(entries=len(sizes), total_bytes=sum(sizes),
                         budget_bytes=self.budget_bytes, hits=self.hits,
                         misses=self.misses, evictions=self.evictions)

    # -- tuning records (see repro.backend.autotune) -------------------------
    def tune_path(self, key: str) -> str:
        return os.path.join(self.root, TUNE_DIR, key + ".tune.json")

    def load_tuning(self, key: str) -> Optional[Dict]:
        try:
            with open(self.tune_path(key)) as fh:
                rec = json.load(fh)
            if rec.get("format") != ENTRY_FORMAT:
                raise ValueError(f"format {rec.get('format')!r}")
            return rec
        except FileNotFoundError:
            return None
        except Exception:
            # torn write / stale format: evict like corrupt compile
            # entries so the next sweep can re-record cleanly
            self.remove_tuning(key)
            return None

    def remove_tuning(self, key: str) -> bool:
        """Evict one tuning record (corrupt or invalidated)."""
        removed = self._remove(self.tune_path(key))
        if removed:
            self.evictions += 1
        return removed

    def store_tuning(self, key: str, record: Dict) -> None:
        try:
            os.makedirs(os.path.join(self.root, TUNE_DIR), exist_ok=True)
            self._atomic_write(self.tune_path(key),
                               json.dumps(record, sort_keys=True))
        except Exception:
            pass


def _options_doc(options) -> Dict[str, Any]:
    """The stable option fields, for entry introspection (cache_tool ls)."""
    out = {}
    for f in dataclasses.fields(options):
        v = getattr(options, f.name)
        if v is None or isinstance(v, (bool, int, float, str)):
            out[f.name] = v
        elif isinstance(v, (tuple, list)):
            out[f.name] = list(v)
        else:
            out[f.name] = repr(v)
    return out
