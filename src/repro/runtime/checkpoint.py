"""Sharding-aware checkpointing with async save and elastic restore.

Design (targets 1000+ nodes; degenerates cleanly to 1 process here):
  * a checkpoint is a directory: ``manifest.json`` + one ``.npy`` per
    tensor (per-process file subsets on a real cluster);
  * the manifest stores *logical* shapes/dtypes + step + data-pipeline
    state, never mesh shape — restore re-shards onto whatever mesh exists
    (elastic scaling: restore on a different chip count just works);
  * saves are atomic (tmp dir + rename) so a node failure mid-save never
    corrupts the latest checkpoint;
  * ``AsyncCheckpointer`` snapshots to host memory synchronously and
    writes on a background thread, keeping the train loop running.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Any, Dict, List, Optional

import numpy as np


def _np(x) -> np.ndarray:
    return np.asarray(x)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:010d}")

    def latest_step(self) -> Optional[int]:
        steps = []
        for d in os.listdir(self.directory):
            if d.startswith("step_") and \
                    os.path.exists(os.path.join(self.directory, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
        return max(steps) if steps else None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tensors: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> str:
        host = {k: _np(v) for k, v in tensors.items()}
        return self._write(step, host, extra or {})

    def _write(self, step: int, host: Dict[str, np.ndarray],
               extra: Dict[str, Any]) -> str:
        final = self._step_dir(step)
        tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=self.directory)
        try:
            manifest = {
                "step": step,
                "time": time.time(),
                "extra": extra,
                "tensors": {
                    k: {"shape": list(v.shape), "dtype": v.dtype.str}
                    for k, v in host.items()
                },
            }
            for k, v in host.items():
                np.save(os.path.join(tmp, self._fname(k)), v, allow_pickle=False)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
        except BaseException:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        self._gc()
        return final

    @staticmethod
    def _fname(key: str) -> str:
        return key.replace("/", "__") + ".npy"

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_"))
        for s in steps[:-self.keep]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def restore(self, step: Optional[int] = None, shardings: Optional[Dict] = None):
        """Load tensors; with ``shardings`` (name -> jax Sharding) the
        arrays are placed sharded (elastic: any mesh shape)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.directory}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        out: Dict[str, Any] = {}
        for k, meta in manifest["tensors"].items():
            arr = np.load(os.path.join(d, self._fname(k)), allow_pickle=False)
            assert list(arr.shape) == meta["shape"], f"{k}: manifest mismatch"
            if shardings and k in shardings:
                import jax

                arr = jax.device_put(arr, shardings[k])
            out[k] = arr
        return manifest["step"], out, manifest.get("extra", {})


class AsyncCheckpointer:
    """Snapshot synchronously, write in the background; at most one
    outstanding save (a newer save waits for the previous write)."""

    def __init__(self, manager: CheckpointManager):
        self.manager = manager
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, tensors: Dict[str, Any],
             extra: Optional[Dict[str, Any]] = None) -> None:
        self.wait()
        host = {k: _np(v).copy() for k, v in tensors.items()}  # snapshot now

        def work():
            try:
                self.manager._write(step, host, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
