"""Runtime substrates: sharding policy, checkpointing, data pipeline,
fault tolerance."""
