"""Fault tolerance scaffolding: retries, heartbeats, straggler detection.

On a real 1000-node cluster the coordinator reschedules failed workers and
this module's pieces run on every host; on one host they degrade to a
watchdog around the step loop.  The contracts that matter at scale:

  * ``retry_step`` — transient failures (preempted chip, flaky link) retry
    with backoff; persistent failures raise so the supervisor restarts
    from the last checkpoint (which ``train.py`` does).
  * ``Heartbeat`` — liveness file per host; a missing heartbeat is how the
    launcher detects a dead node without waiting on a collective timeout.
  * ``StragglerDetector`` — per-step wall-time EMA; steps slower than
    ``threshold``x the EMA are flagged (on a cluster: triggers hot-spare
    swap / re-shard; here: logged + surfaced in metrics).
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, List, Optional


class TransientError(RuntimeError):
    """Raise inside a step for failures that are retry-safe."""


def retry_step(fn: Callable[[], Any], retries: int = 3, backoff: float = 0.5,
               on_retry: Optional[Callable[[int, BaseException], None]] = None):
    attempt = 0
    while True:
        try:
            return fn()
        except TransientError as e:
            attempt += 1
            if attempt > retries:
                raise
            if on_retry:
                on_retry(attempt, e)
            time.sleep(backoff * (2 ** (attempt - 1)))


class Heartbeat:
    def __init__(self, path: str, interval: float = 10.0, host_id: int = 0):
        self.path = path
        self.interval = interval
        self.host_id = host_id
        self._last = 0.0

    def beat(self, step: int, **info) -> None:
        now = time.time()
        if now - self._last < self.interval:
            return
        self._last = now
        tmp = f"{self.path}.tmp"
        with open(tmp, "w") as f:
            json.dump({"host": self.host_id, "step": step, "time": now, **info}, f)
        os.replace(tmp, self.path)

    @staticmethod
    def is_alive(path: str, timeout: float = 60.0) -> bool:
        try:
            with open(path) as f:
                return time.time() - json.load(f)["time"] < timeout
        except (OSError, ValueError, KeyError):
            return False


class StragglerDetector:
    def __init__(self, threshold: float = 2.0, warmup: int = 5, decay: float = 0.9):
        self.threshold = threshold
        self.warmup = warmup
        self.decay = decay
        self.ema: Optional[float] = None
        self.count = 0
        self.stragglers: List[Dict[str, float]] = []

    def record(self, step: int, seconds: float) -> bool:
        """Returns True if this step was a straggler."""
        self.count += 1
        if self.ema is None:
            self.ema = seconds
            return False
        is_straggler = (self.count > self.warmup
                        and seconds > self.threshold * self.ema)
        if is_straggler:
            self.stragglers.append({"step": step, "seconds": seconds,
                                    "ema": self.ema})
        else:  # stragglers don't poison the EMA
            self.ema = self.decay * self.ema + (1 - self.decay) * seconds
        return is_straggler
