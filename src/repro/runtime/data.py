"""Data pipeline: deterministic, shardable, resumable.

Batches are a pure function of (seed, step, shard) — the property the
fault-tolerance story depends on: after restart, resuming at step N
reproduces exactly the batches a non-failed run would have seen, with no
state files beyond the step counter already in the checkpoint.

The synthetic source generates LM token streams with enough structure
(Zipfian marginals + an order-2 Markov mixture) that a real model's loss
visibly falls — used by the end-to-end example and integration tests.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0


class SyntheticLM:
    """Deterministic synthetic LM token source."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.global_batch % cfg.n_shards:
            raise ValueError("global_batch must divide by n_shards")
        self.local_batch = cfg.global_batch // cfg.n_shards
        # fixed Zipf-ish unigram table + deterministic bigram shift
        v = cfg.vocab_size
        ranks = np.arange(1, v + 1, dtype=np.float64)
        self._probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.shard]))
        b, s, v = self.local_batch, cfg.seq_len, cfg.vocab_size
        base = rng.choice(v, size=(b, s + 1), p=self._probs)
        # order-2 structure: with p=0.5 the next token = f(prev) (learnable)
        shifted = (base[:, :-1] * 31 + 17) % v
        coin = rng.random(size=(b, s)) < 0.5
        tokens = base[:, :-1].astype(np.int32)
        labels = np.where(coin, shifted, base[:, 1:]).astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with bounded queue (keeps the step loop
    fed while the host builds the next batch)."""

    def __init__(self, source: SyntheticLM, start_step: int = 0, depth: int = 2):
        self.source = source
        self.start_step = start_step
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self.start_step
        while not self._stop.is_set():
            batch = self.source.batch(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self) -> Tuple[int, Dict[str, np.ndarray]]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
