"""DEPRECATED shim — the sharding policy moved to ``repro.backend.sharding``.

This module stays for one release so external snippets keep importing;
in-repo code must use :mod:`repro.backend.sharding` directly
(``scripts/check_deprecated.py`` enforces it).
"""
from __future__ import annotations

import warnings

from ..backend.sharding import (  # noqa: F401
    ARCH_PROFILES,
    DEFAULT_RULES,
    ParamInfo,
    ShardingPolicy,
    infos_to_shardings,
    policy_for,
    policy_for_arch,
)

warnings.warn(
    "repro.runtime.distributed is deprecated; import from "
    "repro.backend.sharding instead",
    DeprecationWarning, stacklevel=2)
