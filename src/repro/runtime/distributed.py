"""Sharding policy: map IR parameter/batch tensors onto the mesh.

Policies implement the parallelism mix (DP across pod+data, FSDP/ZeRO on
a configurable axis set, TP on 'model', EP for MoE experts) as
PartitionSpecs consumed by pjit.  The policy is *named-axis driven*: model
builders tag every parameter with logical axes ("vocab", "embed", "ffn",
"heads", "experts", ...) and the policy maps logical axes -> mesh axes —
layout abstraction at the distribution level, mirroring what the IR does
per-device (paper sec. 2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class ParamInfo:
    """Logical description of one parameter tensor."""

    name: str
    shape: Tuple[int, ...]
    dtype: Any
    logical_axes: Tuple[Optional[str], ...]  # one entry per dim


# logical axis -> mesh axes, per policy profile
DEFAULT_RULES: Dict[str, Tuple[str, ...]] = {
    "batch": ("pod", "data"),  # batch dims (pod filtered out on 1-pod mesh)
    "vocab": ("model",),
    "embed": ("zero",),        # ZeRO/FSDP shard of the embedding dim
    "ffn": ("model",),         # TP shard of the hidden dim
    "heads": ("model",),
    "kv_heads": (),            # few kv heads: keep replicated
    "kv_seq": ("model",),      # decode KV caches: sequence-shard on model
    "experts": ("expert",),    # resolved to real axes by the profile
    "expert_ffn": (),
    "layers": (),              # stacked-layer leading dim stays unsharded
    "conv": (),
    "seq": (),
    "state": (),
    None: (),
}


@dataclasses.dataclass
class ShardingPolicy:
    """Maps logical axes to mesh axes and produces PartitionSpecs."""

    rules: Dict[str, Tuple[str, ...]]
    zero_axes: Tuple[str, ...] = ("data",)   # FSDP axes for 'embed'-tagged dims
    expert_axes: Tuple[str, ...] = ("model",)
    batch_axes: Tuple[str, ...] = ("data",)  # + 'pod' when present

    def resolve(self, logical: Optional[str]) -> Tuple[str, ...]:
        axes = self.rules.get(logical, ())
        out = []
        for a in axes:
            if a == "expert":
                out.extend(self.expert_axes)
            elif a == "zero":
                out.extend(self.zero_axes)
            else:
                out.append(a)
        return tuple(out)

    def spec_for(self, info: ParamInfo, mesh) -> "jax.sharding.PartitionSpec":
        from jax.sharding import PartitionSpec

        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        used = set()
        entries: List[Any] = []
        for dim, logical in zip(info.shape, info.logical_axes):
            axes = [a for a in self.resolve(logical)
                    if a in sizes and a not in used]
            # keep only axes that divide the dim evenly
            keep: List[str] = []
            prod = 1
            for a in axes:
                if dim % (prod * sizes[a]) == 0:
                    keep.append(a)
                    prod *= sizes[a]
            used.update(keep)
            if not keep:
                entries.append(None)
            elif len(keep) == 1:
                entries.append(keep[0])
            else:
                entries.append(tuple(keep))
        return PartitionSpec(*entries)

    def sharding_for(self, info: ParamInfo, mesh):
        from jax.sharding import NamedSharding

        return NamedSharding(mesh, self.spec_for(info, mesh))

    def batch_spec(self, mesh, rank: int = 2):
        """Batch tensors: leading dim over (pod+)data axes."""
        from jax.sharding import PartitionSpec

        axes = tuple(a for a in ("pod",) + tuple(self.batch_axes)
                     if a in mesh.axis_names)
        axes = tuple(dict.fromkeys(axes))
        lead = axes if len(axes) > 1 else (axes[0] if axes else None)
        return PartitionSpec(lead, *([None] * (rank - 1)))

    def replicated(self, mesh):
        from jax.sharding import NamedSharding, PartitionSpec

        return NamedSharding(mesh, PartitionSpec())

    def as_rules(self) -> Dict[str, Tuple[str, ...]]:
        """Flat logical->mesh-axes table for the ShardingConstraint
        emitter (jax_backend): every known logical name, resolved."""
        return {k: self.resolve(k) for k in self.rules if k is not None}

    def input_sharding(self, mesh, shape, logical_spec):
        """NamedSharding for a data input from its logical per-dim spec."""
        info = ParamInfo("_input", tuple(shape), None, tuple(logical_spec))
        return self.sharding_for(info, mesh)


def policy_for(profile: str = "default", mesh=None) -> ShardingPolicy:
    """Profiles implement per-arch parallelism mixes (DESIGN.md sec. 5)."""
    rules = dict(DEFAULT_RULES)
    if profile == "default":
        return ShardingPolicy(rules)
    if profile == "zero3_pod":
        # shard the FSDP ('embed') dims across pods too: ZeRO-3 over all chips
        return ShardingPolicy(rules, zero_axes=("pod", "data"))
    if profile == "expert_parallel":
        # MoE: experts across data*model (EP), used when E divides the product
        return ShardingPolicy(rules, expert_axes=("data", "model"))
    if profile == "zero3_pod_ep":
        # deepseek-v3: ZeRO-3 across pods + 256-way expert parallelism
        return ShardingPolicy(rules, zero_axes=("pod", "data"),
                              expert_axes=("data", "model"))
    if profile == "expert_tp":
        # MoE with few experts: shard inside each expert instead
        rules["experts"] = ()
        rules["expert_ffn"] = ("model",)
        return ShardingPolicy(rules)
    raise KeyError(f"unknown sharding profile {profile}")


# per-arch parallelism profile (DESIGN.md sec. 5)
ARCH_PROFILES: Dict[str, str] = {
    "deepseek-v3-671b": "zero3_pod_ep",
    "mixtral-8x22b": "expert_tp",
}


def policy_for_arch(arch_name: str) -> ShardingPolicy:
    return policy_for(ARCH_PROFILES.get(arch_name, "default"))


def infos_to_shardings(policy: ShardingPolicy, infos: Sequence[ParamInfo], mesh):
    return [policy.sharding_for(i, mesh) for i in infos]
