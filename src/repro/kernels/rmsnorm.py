"""Pallas TPU RMSNorm kernel.

Tiling: grid over row blocks; each kernel instance holds a
(block_rows, d) tile of x plus the full (d,) weight in VMEM, computes the
row-wise rms in f32 on the VPU, and writes the normalized tile.  d is the
minor (lane) dimension so the reduction is over the 128-wide lane axis;
block_rows is sized so the tile stays well under VMEM (~2 MiB budget).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * w[None, :]).astype(o_ref.dtype)


def pick_block_rows(n_rows: int, d: int, budget_bytes: int = 2 << 20) -> int:
    """Largest power-of-two row block (>=8 sublanes) fitting the budget."""
    rows = max(budget_bytes // max(d * 4, 1), 8)
    rows = 1 << (rows.bit_length() - 1)
    while rows > 8 and n_rows % rows:
        rows //= 2
    return rows


@functools.partial(jax.jit, static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm_fwd(x: jax.Array, w: jax.Array, eps: float = 1e-6,
                block_rows: int = 0, interpret: bool = False) -> jax.Array:
    """x: (..., d) flattened to rows; w: (d,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    x2 = x.reshape(rows, d)
    br = block_rows or pick_block_rows(rows, d)
    if rows % br:
        raise ValueError(f"rows {rows} not divisible by block {br}")
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig_shape)
