"""jit'd wrappers + support predicates for the Pallas kernel library.

This module is the *kernel-selection surface* the JAX transformer consults
(paper sec. 4: transformers combine "tensor-element layout and shape
management with backend kernel selection").  Each ``*_supported`` predicate
encodes the shape/alignment constraints of the corresponding TPU kernel;
unsupported shapes fall back to the transformer's generic emission.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention as _flash
from .matmul import matmul as _matmul
from .norm_matmul import norm_matmul as _norm_matmul
from .rmsnorm import rmsnorm_fwd as _rmsnorm
from .swiglu import swiglu as _swiglu
from .xla_attention import chunked_attention  # noqa: F401  (re-export)

_SUBLANE = 8
_LANE = 128


def _pick_block(size: int, target: int, align: int) -> Optional[int]:
    """Largest divisor of ``size`` that is <= target and a multiple of
    ``align`` (or == size when size < align)."""
    if size <= align:
        return size
    b = min(target, size)
    b -= b % align
    while b >= align:
        if size % b == 0:
            return b
        b -= align
    return size if size % align == 0 or size <= align else None


# -- rmsnorm -----------------------------------------------------------------
def rmsnorm_supported(shape: Tuple[int, ...]) -> bool:
    if len(shape) < 1:
        return False
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    return d % _LANE == 0 and rows % _SUBLANE == 0


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6,
            interpret: bool = True) -> jax.Array:
    return _rmsnorm(x, w, eps=eps, interpret=interpret)


# -- matmul --------------------------------------------------------------------
def matmul_supported(m: int, k: int, n: int) -> bool:
    return m % _LANE == 0 and k % _LANE == 0 and n % _LANE == 0


def matmul(a: jax.Array, b: jax.Array, interpret: bool = True, **kw) -> jax.Array:
    M, K = a.shape
    _, N = b.shape
    bm = _pick_block(M, kw.pop("bm", 256), _LANE) or M
    bn = _pick_block(N, kw.pop("bn", 256), _LANE) or N
    bkk = _pick_block(K, kw.pop("bk", 512), _LANE) or K
    return _matmul(a, b, bm=bm, bn=bn, bk=bkk, interpret=interpret)


# -- fused swiglu ---------------------------------------------------------------
def swiglu_supported(m: int, d: int, f: int, do: int) -> bool:
    """Fused MLP kernel: lane-aligned widths, sublane-aligned rows."""
    return (d % _LANE == 0 and f % _LANE == 0 and do % _LANE == 0
            and m % _SUBLANE == 0 and m > 0)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, interpret: bool = True, **kw) -> jax.Array:
    M, _D = x.shape
    F = w_gate.shape[1]
    Do = w_down.shape[1]
    bm = _pick_block(M, kw.pop("bm", 128), _SUBLANE) or M
    bn = _pick_block(Do, kw.pop("bn", 256), _LANE) or Do
    bf = _pick_block(F, kw.pop("bk", 256), _LANE) or F
    return _swiglu(x, w_gate, w_up, w_down, bm=bm, bn=bn, bf=bf,
                   interpret=interpret)


# -- fused norm+matmul ----------------------------------------------------------
def norm_matmul_supported(m: int, d: int, n: int) -> bool:
    return d % _LANE == 0 and n % _LANE == 0 and m % _SUBLANE == 0 and m > 0


def norm_matmul(x: jax.Array, g: jax.Array, w: jax.Array,
                eps: float = 1e-6, interpret: bool = True,
                **kw) -> jax.Array:
    M, _D = x.shape
    N = w.shape[1]
    bm = _pick_block(M, kw.pop("bm", 128), _SUBLANE) or M
    bn = _pick_block(N, kw.pop("bn", 256), _LANE) or N
    return _norm_matmul(x, g, w, eps=eps, bm=bm, bn=bn, interpret=interpret)


# -- attention ------------------------------------------------------------------
def attention_supported(q_shape: Tuple[int, ...],
                        k_shape: Tuple[int, ...]) -> bool:
    """Flash kernel constraints: 4D BHSD, Sq/Skv tileable, D lane-aligned."""
    if len(q_shape) != 4 or len(k_shape) != 4:
        return False
    B, Hq, Sq, Dk = q_shape
    _, Hkv, Skv, _ = k_shape
    if Hkv == 0 or Hq % Hkv:
        return False
    if Dk % _LANE:
        return False
    bq = _pick_block(Sq, 256, _LANE)
    bk = _pick_block(Skv, 512, _LANE)
    return bq is not None and bk is not None


def flash_attention(q, k, v, *, causal=True, window=None, scale=None,
                    q_offset=None, interpret: bool = True) -> jax.Array:
    B, Hq, Sq, Dk = q.shape
    Skv = k.shape[2]
    bq = _pick_block(Sq, 256, _LANE) or Sq
    bk = _pick_block(Skv, 512, _LANE) or Skv
    return _flash(q, k, v, causal=causal, window=window, scale=scale,
                  q_offset=q_offset, bq=bq, bk=bk, interpret=interpret)
