"""Pure-jnp oracles for every Pallas kernel (the MKL-DNN-analogue layer's
reference semantics).  Kernel tests sweep shapes/dtypes and
assert_allclose against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: Optional[jax.Array] = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    off = q_offset if q_offset is not None else 0
    qpos = jnp.arange(Sq)[:, None] + off
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
