"""Pure-jnp oracles for every Pallas kernel (the MKL-DNN-analogue layer's
reference semantics).  Kernel tests sweep shapes/dtypes and
assert_allclose against these."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)
    return out.astype(x.dtype)


def matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
               w_down: jax.Array) -> jax.Array:
    """matmul(silu(x @ w_gate) * (x @ w_up), w_down), f32 accumulate."""
    g = jax.nn.silu(matmul_ref(x, w_gate))
    u = matmul_ref(x, w_up)
    return matmul_ref((g * u).astype(x.dtype), w_down)


def norm_matmul_ref(x: jax.Array, g: jax.Array, w: jax.Array,
                    eps: float = 1e-6) -> jax.Array:
    return matmul_ref(rmsnorm_ref(x, g, eps=eps), w)


def rotary_qkv_ref(x: jax.Array, wq: jax.Array, wk: jax.Array,
                   wv: jax.Array, cos: jax.Array, sin: jax.Array, *,
                   n_heads: int, n_kv: int):
    """Fused QKV projection + rotate-half rope; returns (q, k, v) BHSD."""
    B, S, _D = x.shape

    def split(y, h):
        return y.reshape(B, S, h, -1).transpose(0, 2, 1, 3)

    def rope(t):
        half = t.shape[-1] // 2
        x1, x2 = t[..., :half], t[..., half:]
        c = cos[None, None].astype(t.dtype)
        s = sin[None, None].astype(t.dtype)
        return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)

    q = rope(split(matmul_ref(x, wq), n_heads))
    k = rope(split(matmul_ref(x, wk), n_kv))
    v = split(matmul_ref(x, wv), n_kv)
    return q, k, v


def attention_ref(
    q: jax.Array,  # (B, Hq, Sq, D)
    k: jax.Array,  # (B, Hkv, Skv, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: Optional[jax.Array] = None,
) -> jax.Array:
    B, Hq, Sq, D = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    if scale is None:
        scale = 1.0 / (D ** 0.5)
    if Hq != Hkv:
        k = jnp.repeat(k, Hq // Hkv, axis=1)
        v = jnp.repeat(v, Hq // Hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    off = q_offset if q_offset is not None else 0
    qpos = jnp.arange(Sq)[:, None] + off
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = mask & (kpos <= qpos)
    if window is not None:
        mask = mask & (kpos > qpos - window)
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v)
