"""Pallas TPU flash attention (the compound-kernel the Attention IR op
selects on the TPU backend — the MKL-DNN-analogue for attention).

TPU-native adaptation of the flash algorithm (paper's GPU kernels have no
warp/SM analogue here):

  * grid = (B, Hq, Sq/bq, Skv/bk) with the KV dimension innermost and
    ``dimension_semantics=("parallel","parallel","parallel","arbitrary")``
    so the output tile stays resident in VMEM across the KV sweep;
  * per-(b,h,q-block) running max / sum / accumulator live in VMEM
    scratch shaped (bq, 128) / (bq, Dv) — lane-replicated the way the
    official TPU flash kernel does it, so the VPU reductions stay on the
    128-wide lane axis;
  * GQA is free: the k/v BlockSpec index_map maps query head h to kv head
    h // (Hq // Hkv), so no head-repeat materialization;
  * causal/window masking is positional (q_offset supports decode with a
    prefilled cache);  blocks entirely outside the mask are skipped via
    ``pl.when`` (no MXU work, no accumulator update);
  * Dv may differ from Dk (MLA-style latent attention).

Block shapes default to (bq, bk) = (256, 512) with Dk/Dv up to 256:
q-tile 256x256xf32 (256 KB) + k/v tiles 512x256 (512 KB) + acc (256 KB)
stays well under the ~16 MiB VMEM budget and all MXU dims are multiples
of 128.  Validated in interpret mode against ``ref.attention_ref``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
_LANES = 128


def _flash_kernel(off_ref,  # scalar prefetch: (1,) i32 q position offset
                  q_ref, k_ref, v_ref,  # (1,1,bq,Dk), (1,1,bk,Dk), (1,1,bk,Dv)
                  o_ref,  # (1,1,bq,Dv)
                  m_ref, l_ref, acc_ref,  # VMEM scratch
                  *, scale: float, causal: bool, window: Optional[int],
                  bq: int, bk: int, n_k: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # absolute positions of this block's queries / keys
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + off_ref[0]
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    # block-level skip: the whole kv block is after every query (causal),
    # or before every query's window
    q_first = qi * bq + off_ref[0]
    q_last = q_first + bq - 1
    k_first = ki * bk
    k_last = k_first + bk - 1
    run = jnp.asarray(True)
    if causal:
        run = jnp.logical_and(run, k_first <= q_last)
    if window is not None:
        run = jnp.logical_and(run, k_last > q_first - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((bq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]  # (bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)  # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        # guard fully-masked rows (m_new == NEG_INF): exp(NEG_INF-NEG_INF)=1
        # would pollute l; rescale with 0 instead.
        row_dead = m_new <= NEG_INF / 2
        p = jnp.exp(s - jnp.where(row_dead, 0.0, m_new))
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(jnp.where(row_dead, 0.0, m_prev - m_new))
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        v = v_ref[0, 0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha + pv
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ki == n_k - 1)
    def _flush():
        l = l_ref[:, :1]
        safe_l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe_l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "bq", "bk", "interpret"))
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, Dk)
    k: jax.Array,  # (B, Hkv, Skv, Dk)
    v: jax.Array,  # (B, Hkv, Skv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: Optional[jax.Array] = None,
    bq: int = 256,
    bk: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, Hq, Sq, Dk = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / (Dk ** 0.5)
    rep = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Skv)
    if Sq % bq or Skv % bk:
        raise ValueError(f"Sq={Sq} % bq={bq} or Skv={Skv} % bk={bk} != 0")
    n_k = Skv // bk
    off = jnp.zeros((1,), jnp.int32) if q_offset is None else \
        jnp.reshape(q_offset, (1,)).astype(jnp.int32)

    grid = (B, Hq, Sq // bq, n_k)
    kernel = functools.partial(
        _flash_kernel, scale=float(scale), causal=causal, window=window,
        bq=bq, bk=bk, n_k=n_k)
    kw = {}
    if not interpret:
        kw["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                # index_maps receive (*grid_indices, *scalar_prefetch_refs)
                pl.BlockSpec((1, 1, bq, Dk),
                             lambda b, h, i, j, *_: (b, h, i, 0)),
                pl.BlockSpec((1, 1, bk, Dk),
                             lambda b, h, i, j, *_, rep=rep: (b, h // rep, j, 0)),
                pl.BlockSpec((1, 1, bk, Dv),
                             lambda b, h, i, j, *_, rep=rep: (b, h // rep, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, bq, Dv),
                                   lambda b, h, i, j, *_: (b, h, i, 0)),
            scratch_shapes=[
                pltpu.VMEM((bq, _LANES), jnp.float32),
                pltpu.VMEM((bq, _LANES), jnp.float32),
                pltpu.VMEM((bq, Dv), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, Dv), q.dtype),
        interpret=interpret,
        **kw,
    )(off, q, k, v)
