"""Pallas TPU fused RMSNorm+matmul kernel.

Computes ``matmul(rms_norm(x, g, eps), w)`` — the pre-attention / pre-MLP
projection pattern.  Grid (M/bm, N/bn): each instance keeps a full-width
(bm, D) row tile of x in VMEM, normalizes it in f32 on the VPU, and
contracts the normalized rows against the (D, bn) weight column block on
the MXU.  The normalized activation is recomputed per N block instead of
round-tripping through HBM (D reads beat D writes + D reads; whether
that wins on a given shape is the autotuner's call via the
``fuse_norm_matmul`` knob).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _norm_matmul_kernel(x_ref, g_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    nrm = (x * jax.lax.rsqrt(var + eps) * g[None, :]).astype(x_ref.dtype)
    o_ref[...] = jnp.dot(nrm, w_ref[...],
                         preferred_element_type=jnp.float32).astype(
                             o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "bm", "bn", "interpret"))
def norm_matmul(x: jax.Array, g: jax.Array, w: jax.Array,
                eps: float = 1e-6, bm: int = 128, bn: int = 256,
                interpret: bool = False) -> jax.Array:
    """x: (M, D); g: (D,); w: (D, N) -> (M, N)."""
    M, D = x.shape
    N = w.shape[1]
    bm, bn = min(bm, M), min(bn, N)
    if M % bm or N % bn:
        xf = x.astype(jnp.float32)
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        nrm = (xf * jax.lax.rsqrt(var + eps)
               * g.astype(jnp.float32)).astype(x.dtype)
        return jnp.dot(nrm, w,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    return pl.pallas_call(
        functools.partial(_norm_matmul_kernel, eps=eps),
        grid=(M // bm, N // bn),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j: (i, 0)),
            pl.BlockSpec((D,), lambda i, j: (0,)),
            pl.BlockSpec((D, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        interpret=interpret,
    )(x, g, w)
