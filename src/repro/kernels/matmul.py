"""Pallas TPU tiled matmul.

Canonical MXU tiling: grid (M/bm, N/bn, K/bk) with the K dimension
innermost ("arbitrary" semantics) accumulating f32 partials straight into
the output tile, which stays resident in VMEM across the K sweep (its
index_map ignores the k grid index).  All tile dims are multiples of 128
to match the 128x128 systolic array; inputs feed the MXU in bf16 with f32
accumulation (``preferred_element_type``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def matmul(a: jax.Array, b: jax.Array, bm: int = 256, bn: int = 256,
           bk: int = 512, interpret: bool = False) -> jax.Array:
    """a: (M, K) @ b: (K, N) -> (M, N); tile dims must divide shapes."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    bm, bn, bk = min(bm, M), min(bn, N), min(bk, K)
    if M % bm or N % bn or K % bk:
        # No legal tiling for this shape: lower to the XLA dot (same
        # f32-accumulate numerics) instead of failing the compile —
        # autotune sweeps over odd shapes must never crash a candidate.
        return jnp.dot(a, b,
                       preferred_element_type=jnp.float32).astype(a.dtype)
    n_k = K // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=(M // bm, N // bn, n_k),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
        interpret=interpret,
    )(a, b)
