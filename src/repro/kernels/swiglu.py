"""Pallas TPU fused SwiGLU MLP kernel.

Computes ``matmul(silu(x @ w_gate) * (x @ w_up), w_down)`` with the gate
activation resident in VMEM: grid (M/bm, Do/bn, F/bf) with the ffn
contraction (F) innermost ("arbitrary" semantics).  Each instance holds a
full-width (bm, D) row tile of x, produces the (bm, bf) gate/up slab on
the MXU, applies silu*mul on the VPU, and accumulates the down-projection
straight into an f32 VMEM scratch tile — the (M, F) hidden activation
never exists in HBM, which is the entire point of the fusion (the
unfused emission writes and re-reads it once per token).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _swiglu_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref, acc_ref, *,
                   n_f: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]
    # mirror ref.py's composition: f32 MXU accumulate, cast back to the
    # input dtype between stages (bit-comparable with the XLA fallback)
    g = jnp.dot(x, wg_ref[...],
                preferred_element_type=jnp.float32).astype(x.dtype)
    u = jnp.dot(x, wu_ref[...],
                preferred_element_type=jnp.float32).astype(x.dtype)
    h = jax.nn.silu(g) * u
    acc_ref[...] += jnp.dot(h.astype(x.dtype), wd_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == n_f - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bf", "interpret"))
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array, bm: int = 128, bn: int = 256, bf: int = 256,
           interpret: bool = False) -> jax.Array:
    """x: (M, D); w_gate/w_up: (D, F); w_down: (F, Do) -> (M, Do)."""
    M, D = x.shape
    F = w_gate.shape[1]
    Do = w_down.shape[1]
    bm, bn, bf = min(bm, M), min(bn, Do), min(bf, F)
    if M % bm or Do % bn or F % bf:
        g = jnp.dot(x, w_gate,
                    preferred_element_type=jnp.float32).astype(x.dtype)
        u = jnp.dot(x, w_up,
                    preferred_element_type=jnp.float32).astype(x.dtype)
        return jnp.dot(jax.nn.silu(g) * u, w_down,
                       preferred_element_type=jnp.float32).astype(x.dtype)
    n_f = F // bf
    return pl.pallas_call(
        functools.partial(_swiglu_kernel, n_f=n_f),
        grid=(M // bm, Do // bn, n_f),
        in_specs=[
            pl.BlockSpec((bm, D), lambda i, j, f: (i, 0)),
            pl.BlockSpec((D, bf), lambda i, j, f: (0, f)),
            pl.BlockSpec((D, bf), lambda i, j, f: (0, f)),
            pl.BlockSpec((bf, bn), lambda i, j, f: (f, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, f: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, Do), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))
        if not interpret else None,
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
