"""Memory-efficient (chunked online-softmax) attention in pure XLA.

This is the *XLA-backend* realization of the Attention compound op for
long sequences — the flash algorithm expressed with ``lax.scan`` over KV
chunks, so peak memory is O(Sq * bk) instead of O(Sq * Skv).  The Pallas
kernel (``flash_attention.py``) is the TPU-transformer realization; this
one compiles on any XLA backend (and is what the 512-device dry run
lowers, since Pallas TPU kernels cannot compile on the CPU backend).

Semantics identical to ``ref.attention_ref``: GQA, causal, sliding
window, decode q_offset, Dv != Dk.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "scale", "bk"))
def chunked_attention(
    q: jax.Array,  # (B, Hq, Sq, Dk)
    k: jax.Array,  # (B, Hkv, Skv, Dk)
    v: jax.Array,  # (B, Hkv, Skv, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    scale: Optional[float] = None,
    q_offset: Optional[jax.Array] = None,
    bk: int = 1024,
) -> jax.Array:
    B, Hq, Sq, Dk = q.shape
    _, Hkv, Skv, _ = k.shape
    Dv = v.shape[-1]
    if scale is None:
        scale = 1.0 / (Dk ** 0.5)
    rep = Hq // Hkv
    bk = min(bk, Skv)
    if Skv % bk:
        raise ValueError(f"Skv={Skv} not divisible by chunk {bk}")
    n_chunks = Skv // bk

    off = jnp.asarray(0, jnp.int32) if q_offset is None else \
        jnp.asarray(q_offset, jnp.int32).reshape(())
    q_pos = jnp.arange(Sq, dtype=jnp.int32) + off  # (Sq,)

    # grouped query layout (B, Hkv, rep, Sq, Dk): contraction against
    # un-repeated kv — no head-repeat materialization.
    qg = q.reshape(B, Hkv, rep, Sq, Dk)
    kc = k.reshape(B, Hkv, n_chunks, bk, Dk).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, bk, Dv).transpose(2, 0, 1, 3, 4)
    chunk_ids = jnp.arange(n_chunks, dtype=jnp.int32)

    def step(carry, chunk):
        m_prev, l_prev, acc = carry
        ci, kb, vb = chunk  # (), (B,Hkv,bk,Dk), (B,Hkv,bk,Dv)
        k_pos = ci * bk + jnp.arange(bk, dtype=jnp.int32)  # (bk,)
        s = jnp.einsum("bgrqd,bgkd->bgrqk", qg.astype(jnp.float32),
                       kb.astype(jnp.float32),
                       preferred_element_type=jnp.float32) * scale
        mask = jnp.ones((Sq, bk), dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            mask = jnp.logical_and(mask, k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_cur = jnp.max(s, axis=-1)  # (B,Hkv,rep,Sq)
        m_new = jnp.maximum(m_prev, m_cur)
        m_safe = jnp.where(m_new <= NEG_INF / 2, 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, None, None], p, 0.0)
        alpha = jnp.exp(jnp.where(m_new <= NEG_INF / 2, 0.0, m_prev - m_new))
        l_new = alpha * l_prev + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bgrqk,bgkd->bgrqd", p, vb.astype(jnp.float32),
                        preferred_element_type=jnp.float32)
        acc = acc * alpha[..., None] + pv
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, Hkv, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, rep, Sq), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, rep, Sq, Dv), jnp.float32)
    (m, l, acc), _ = lax.scan(step, (m0, l0, acc0), (chunk_ids, kc, vc))
    l_safe = jnp.where(l == 0.0, 1.0, l)
    out = (acc / l_safe[..., None]).astype(q.dtype)
    return out.reshape(B, Hq, Sq, Dv)
