"""neon-style framework bridge.

The paper (sec. 3): "For neon, we are creating a Python binding for the
nGraph API".  This module is a miniature layer-object framework (the kind
of API neon exposed) whose *backend is the bridge*: ``bridge_to_ir`` walks
the layer graph and emits nGraph IR; training graphs come from IR autodiff
(sec. 3: bridges use "autodiff on the nGraph IR for the derivative").
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core import ops
from ..core.autodiff import GradBuilder, zeros_of
from ..core.function import Function
from ..core.node import Node, Value


class Layer:
    """A stateful layer object (framework side — state lives here, not in
    the stateless IR)."""

    def params(self) -> Dict[str, np.ndarray]:
        return {}

    def build(self, x: Value, get_param) -> Value:
        raise NotImplementedError


class Dense(Layer):
    def __init__(self, n_in: int, n_out: int, activation: Optional[str] = None,
                 bias: bool = True, name: str = "dense", seed: int = 0):
        self.name = name
        rng = np.random.default_rng(seed)
        scale = 1.0 / math.sqrt(n_in)
        self._params = {f"{name}/w": (rng.normal(size=(n_in, n_out)) * scale).astype(np.float32)}
        if bias:
            self._params[f"{name}/b"] = np.zeros((n_out,), np.float32)
        self.activation = activation
        self.bias = bias

    def params(self):
        return self._params

    def build(self, x: Value, get_param) -> Value:
        y = ops.matmul(x, get_param(f"{self.name}/w"))
        if self.bias:
            y = y + get_param(f"{self.name}/b")
        if self.activation:
            y = getattr(ops, self.activation)(y)
        return y


class Embedding(Layer):
    def __init__(self, vocab: int, dim: int, name: str = "emb", seed: int = 0):
        self.name = name
        rng = np.random.default_rng(seed)
        self._params = {f"{name}/table": (rng.normal(size=(vocab, dim)) * 0.02).astype(np.float32)}

    def params(self):
        return self._params

    def build(self, x: Value, get_param) -> Value:
        return ops.gather(get_param(f"{self.name}/table"), x, axis=0)


class RMSNormLayer(Layer):
    def __init__(self, dim: int, name: str = "rmsnorm"):
        self.name = name
        self._params = {f"{name}/g": np.ones((dim,), np.float32)}

    def params(self):
        return self._params

    def build(self, x: Value, get_param) -> Value:
        return ops.rms_norm(x, get_param(f"{self.name}/g"))


class LayerNormLayer(Layer):
    def __init__(self, dim: int, name: str = "layernorm"):
        self.name = name
        self._params = {f"{name}/g": np.ones((dim,), np.float32),
                        f"{name}/b": np.zeros((dim,), np.float32)}

    def params(self):
        return self._params

    def build(self, x: Value, get_param) -> Value:
        return ops.layer_norm(x, get_param(f"{self.name}/g"), get_param(f"{self.name}/b"))


class Sequential(Layer):
    def __init__(self, layers: Sequence[Layer]):
        self.layers = list(layers)

    def params(self):
        out = {}
        for l in self.layers:
            out.update(l.params())
        return out

    def build(self, x: Value, get_param) -> Value:
        for l in self.layers:
            x = l.build(x, get_param)
        return x


class Model:
    """Framework-side model: owns parameter arrays + a layer graph."""

    def __init__(self, net: Layer):
        self.net = net
        self.param_values: Dict[str, np.ndarray] = dict(net.params())

    def param_names(self) -> List[str]:
        return sorted(self.param_values)


def bridge_to_ir(
    model: Model,
    input_shape: Sequence[int],
    input_dtype="f32",
    loss: Optional[str] = None,
    label_shape: Optional[Sequence[int]] = None,
    with_grads: bool = False,
) -> Tuple[Function, List[str]]:
    """Translate the framework graph to an nGraph Function.

    Returns (function, param_order): function params are
    [input, (labels), *params-in-order].  With ``with_grads``, results are
    [loss/output, *grads] computed by autodiff on the IR.
    """
    names = model.param_names()
    x_p = ops.parameter(input_shape, input_dtype, "input")
    label_p = None
    if loss is not None:
        if label_shape is None:
            raise ValueError("loss needs label_shape")
        label_p = ops.parameter(label_shape, "i32", "labels")
    param_nodes = {n: ops.parameter(model.param_values[n].shape,
                                    model.param_values[n].dtype, n)
                   for n in names}

    def get_param(n: str) -> Value:
        return param_nodes[n].out()

    out = model.net.build(x_p.out(), get_param)
    all_params = [x_p] + ([label_p] if label_p else []) + [param_nodes[n] for n in names]
    if loss is None:
        return Function(all_params, [out], name="neon_forward"), names
    if loss == "softmax_xent":
        loss_v = ops.reduce_mean(ops.softmax_cross_entropy(out, label_p.out()))
    elif loss == "mse":
        diff = out - ops.convert(label_p.out(), out.dtype)
        loss_v = ops.reduce_mean(diff * diff)
    else:
        raise ValueError(f"unknown loss {loss}")
    if not with_grads:
        return Function(all_params, [loss_v, out], name="neon_loss"), names
    gb = GradBuilder()
    wrt = [param_nodes[n].out() for n in names]
    grads = gb.backprop([loss_v], [ops.constant(1.0, dtype=loss_v.dtype)], wrt)
    grads = [g if g is not None else zeros_of(v.type) for g, v in zip(grads, wrt)]
    fn = Function(all_params, [loss_v] + grads, name="neon_train")
    return gb.apply_replacements(fn), names


def compile_model(
    model: Model,
    input_shape: Sequence[int],
    *,
    input_dtype="f32",
    loss: Optional[str] = None,
    label_shape: Optional[Sequence[int]] = None,
    with_grads: bool = False,
    backend: str = "jax",
    options=None,
):
    """Bridge ``model`` to IR and compile it on a named backend.

    The neon-style one-call path the paper describes for framework users:
    the bridge emits IR and hands it to the unified Backend API (pipeline,
    kernel selection, and the compile cache all happen behind it).
    Returns ``(compiled, param_order)`` where ``compiled`` is a
    :class:`repro.backend.CompiledFunction`.
    """
    from ..backend import Backend, CompileOptions
    fn, names = bridge_to_ir(model, input_shape, input_dtype=input_dtype,
                             loss=loss, label_shape=label_shape,
                             with_grads=with_grads)
    compiled = Backend.create(backend).compile(fn, options or CompileOptions())
    return compiled, names
