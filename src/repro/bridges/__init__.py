"""Framework bridges (paper sec. 3): adapters translating a framework's
computational graph into nGraph IR.  Here: a neon-style layer API and an
ONNX-like serialized-graph importer; the functional builder in
``repro.core.ops`` plays the role of the native Python binding."""
from .neon import (Dense, Embedding, LayerNormLayer, Model, RMSNormLayer,  # noqa: F401
                   Sequential, bridge_to_ir)
from . import onnx_like  # noqa: F401
