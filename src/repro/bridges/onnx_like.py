"""ONNX-like bridge: import/export serialized graphs (paper sec. 1.1).

A foreign producer can hand us a JSON graph document; we import it as
first-class IR (same Function type every other bridge produces), run the
same passes, and execute on any transformer.
"""
from __future__ import annotations

from ..core import serialize
from ..core.function import Function

export_graph = serialize.dumps
export_file = serialize.save


def import_graph(doc: str) -> Function:
    return serialize.loads(doc)


def import_file(path: str) -> Function:
    return serialize.load(path)
