"""Batched serving: prefill + greedy decode through KV caches
(deliverable b, inference path).

    PYTHONPATH=src python examples/serve_lm.py [--arch mixtral-8x22b]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    sys.exit(main(["--arch", "deepseek-7b", "--reduced", "--batch", "4",
                   "--prompt-len", "32", "--gen", "32"] + sys.argv[1:]))
