"""Quickstart: build IR with the functional frontend, compile it through
the unified Backend API (pipeline + cache included), execute on two
backends, take gradients — the whole nGraph pipeline in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import ng                       # the functional IR frontend
from repro.core import Function
from repro.core.autodiff import grad
from repro.backend import Backend, CompileOptions

# 1. Build a graph: softmax(rms_norm(gelu(x @ w)) * g)
x = ng.parameter((8, 64), "f32", "x")
w = ng.parameter((64, 64), "f32", "w")
g = ng.parameter((64,), "f32", "g")
y = ng.softmax(ng.rms_norm(ng.gelu(ng.matmul(x.out(), w.out())), g.out()), -1)
fn = Function([x, w, g], [y])
print("graph:", fn)

# 2. One compile call runs the pass pipeline AND backend codegen.
#    CompileOptions is the single declarative knob set (opt level, kernel
#    selection, partitioning); the result carries the pipeline report.
jax_be = Backend.create("jax")
compiled = jax_be.compile(fn, CompileOptions(level="O2"))
print(compiled.report.summary())

# 3. The same IR compiles on every backend — and executables support
#    positional or named-parameter calling.
rng = np.random.default_rng(0)
args = dict(x=rng.normal(size=(8, 64)).astype(np.float32),
            w=rng.normal(size=(64, 64)).astype(np.float32),
            g=np.ones(64, np.float32))
ref = Backend.create("interpreter").compile(fn)(**args)[0]
xla = compiled(**args)[0]
print("interpreter vs XLA max|diff|:", np.abs(ref - xla).max())

# 4. Compiles are memoized: a structurally-identical graph with the same
#    options is a cache hit (this is what keeps serving fast).
again = jax_be.compile(fn, CompileOptions(level="O2"))
assert again is compiled
print("compile cache:", jax_be.cache_stats())

# 5. Autodiff ON THE IR (not on traces): a gradient graph, same API
loss_fn = Function([x, w, g], [ng.reduce_mean(fn.results[0] * fn.results[0])])
gfn = grad(loss_fn)
print("grad graph:", len(gfn.nodes()), "nodes")
grads = jax_be.compile(gfn)(**args)
print("dL/dw norm:", float(np.square(np.asarray(grads[2])).sum()) ** 0.5)

# 6. Compile artifacts ride along as metadata: the memory plan (liveness
#    arena) and the IR-level cost estimate.
print("memory plan:", compiled.memory_plan.summary())
print("cost: %.3g flops, %.3g bytes" % (compiled.cost.flops,
                                        compiled.cost.bytes))
