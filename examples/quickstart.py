"""Quickstart: build IR with the functional frontend, run compiler
passes, execute on two transformers, take gradients — the whole nGraph
pipeline in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import ng                       # the functional IR frontend
from repro.core import Function
from repro.core.autodiff import grad
from repro.core.passes import Decompose, FuseCompounds, plan_memory, run_pipeline
from repro.transformers import get_transformer

# 1. Build a graph: softmax(rms_norm(gelu(x @ w)) * g)
x = ng.parameter((8, 64), "f32", "x")
w = ng.parameter((64, 64), "f32", "w")
g = ng.parameter((64,), "f32", "g")
y = ng.softmax(ng.rms_norm(ng.gelu(ng.matmul(x.out(), w.out())), g.out()), -1)
fn = Function([x, w, g], [y])
print("graph:", fn)

# 2. Run the pass pipeline (constant folding / CSE / algebraic / layout)
opt, report = run_pipeline(fn, level="O2")
print(report.summary())

# 3. The same IR executes on every transformer
rng = np.random.default_rng(0)
args = [rng.normal(size=(8, 64)).astype(np.float32),
        rng.normal(size=(64, 64)).astype(np.float32),
        np.ones(64, np.float32)]
ref = get_transformer("interpreter").compile(opt)(*args)[0]
xla = get_transformer("jax").compile(opt)(*args)[0]
print("interpreter vs XLA max|diff|:", np.abs(ref - xla).max())

# 4. Autodiff ON THE IR (not on traces): a gradient graph
loss_fn = Function([x, w, g], [ng.reduce_mean(fn.results[0] * fn.results[0])])
gfn = grad(loss_fn)
print("grad graph:", len(gfn.nodes()), "nodes")
grads = get_transformer("jax").compile(gfn)(*args)
print("dL/dw norm:", float(np.square(np.asarray(grads[2])).sum()) ** 0.5)

# 5. Memory planning: liveness-driven arena with buffer reuse
plan = plan_memory(opt)
print("memory plan:", plan.summary())

# 6. Compounding: decompose to primitives, pattern-match them back
dec, _ = Decompose().run(fn)
fused, stats = FuseCompounds().run(dec)
print("decomposed:", len(dec.nodes()), "nodes -> re-fused:",
      len(fused.nodes()), "nodes; recovered:", stats)
