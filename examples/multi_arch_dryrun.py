"""Lower + compile any assigned architecture on the production mesh and
print its roofline terms (a thin wrapper over repro.launch.dryrun).

    python examples/multi_arch_dryrun.py --arch xlstm-350m --shape train_4k
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.dryrun import main

if __name__ == "__main__":
    sys.exit(main(sys.argv[1:] or ["--arch", "xlstm-350m",
                                   "--shape", "train_4k"]))
