"""Streaming tokens from the ServeEngine (continuous batching).

Three requests with different prompt/generation lengths share two KV
pool slots; tokens stream out as they are produced, and the third
request is admitted mid-flight the moment a slot frees up.

Run:  PYTHONPATH=src python examples/serving_demo.py
"""
import numpy as np

from repro.configs import get_config
from repro.launch.engine import ServeEngine


def main():
    cfg = get_config("deepseek-7b").reduced()
    engine = ServeEngine(cfg, slots=2, max_len=24, mode="continuous", seed=0)

    rng = np.random.default_rng(0)
    workload = [(rng.integers(0, cfg.vocab, size=(6,)), 8),
                (rng.integers(0, cfg.vocab, size=(4,)), 10),
                (rng.integers(0, cfg.vocab, size=(8,)), 6)]
    for prompt, max_new in workload:
        rid = engine.submit(prompt, max_new)
        print(f"submitted req{rid}: prompt={len(prompt)} gen={max_new}")

    print("--- streaming ---")
    for rid, token in engine.stream():
        print(f"req{rid} -> {token}")

    rep = engine.run()  # drained; returns the report
    print("--- report ---")
    print(f"{rep.generated_tokens} tokens, {rep.tok_s:.1f} tok/s e2e, "
          f"{rep.decode_tok_s:.1f} tok/s decode, "
          f"late admissions: {rep.late_admissions}")
    p = rep.pool
    print(f"kv pool: {p.slots} slots x {p.bytes_per_slot}B, "
          f"allocs={p.allocs} frees={p.frees} peak={p.peak_active}")


if __name__ == "__main__":
    main()
