"""Streaming tokens from the ServeEngine (continuous batching).

Three requests with different prompt/generation lengths share two KV
pool slots; tokens stream out as they are produced, and the third
request is admitted mid-flight the moment a slot frees up.

The second half re-runs the workload in ``paged`` mode: KV lives in a
shared page pool (pages allocated as each request's position crosses a
page boundary, returned on completion), the scheduler decodes
``chunk_steps`` tokens per dispatch, and sampling knobs (temperature /
top-k / PRNG key) are per-request graph inputs — greedy requests stay
token-for-token identical to continuous mode while the pool reserves
fewer KV bytes per token actually cached.

The third section puts the HTTP front door (``launch/server.py``) over
a paged engine and talks to it like a network client would: a streaming
``POST /v1/generate`` consumed token by token over SSE, a ``text``
prompt, and the ``GET /v1/metrics`` SLO snapshot — then drains the
server and shows the pool came back empty.

The fourth section exercises the fault-tolerance contract (PR 8):
cancelling a mid-flight request at a chunk boundary (slot and pages
verifiably return), a per-request deadline expiring into its own
``deadline_exceeded`` terminal status, and a deterministic
``FaultInjector`` raising inside dispatch — contained into a structured
per-request failure with the engine degraded but still serving.

The fifth section is the PR 9 prefix-sharing contract: three requests
with one identical 16-token system prompt run on a paged engine with
copy-on-write sharing on (the default) and off.  With sharing on, the
followers point their page tables at the publisher's hashed prefix
pages (``shared_attaches``), copy only on the first divergent write
(``cow_copies``), reserve far fewer KV bytes per active token — and
decode exactly the same greedy tokens, with every refcounted page
released on drain (``pool.verify()`` comes back empty).  Prompts are
prefilled in-graph in bounded chunks (``prefill_chunk``) rather than
one dense dispatch per prompt length.

The sixth section is the PR 10 unified sharding API: one module
(``repro.backend.sharding``) holds the pjit policies, mesh helpers, and
the partition profiles that drive the ``PartitionGraph`` pass —
``CompileOptions(mode="shardmap", partition="tp", mesh_shape=(N,))``
cuts a compiled graph into a per-device program with explicit AllGather
nodes (the exact column-parallel profile never splits a contraction,
so greedy decode stays bit-identical), and ``EngineConfig(tp=2)``
serves the paged engine tensor-parallel: each device holds half the KV
heads of every page while greedy tokens match ``tp=1`` exactly.  The
tp half runs in a subprocess with a forced 2-device CPU mesh.

The final section shows the fused-kernel layer underneath: compiling a
serve-family graph at O2 pattern-matches the unfused matmul chains into
SwiGLU / NormMatmul / RotaryQKV compound ops (per-compound hit counts
in the PipelineReport), and ``autotune=True`` resolves the Pallas
matmul tile shapes and per-compound fusion on/off from a recorded
sweep — candidate 0 is always the request as-given, so the selection
can never be slower than not tuning.

Run:  PYTHONPATH=src python examples/serving_demo.py
"""
import asyncio

import numpy as np

from repro.configs import get_config
from repro.launch import loadgen
from repro.launch.engine import ServeEngine
from repro.launch.server import running_server


def fused_kernel_demo(cfg):
    import tempfile

    from repro.backend import Backend, CompileOptions
    from repro.configs.base import ShapeConfig
    from repro.models.lm import build_graphs

    g = build_graphs(cfg, ShapeConfig("serve", "serve", 16, 2), 2)
    be = Backend.create("jax", fresh=True)
    # what the pattern-matcher finds, before the tuner weighs in
    cf = be.compile(g.fn, CompileOptions(level="O2", use_pallas=True,
                                         interpret_pallas=True))
    hits = dict(cf.report.stats)["fuse-compounds"]
    print("compounds fused at O2:",
          {k: v for k, v in hits.items() if v})
    # the tuner sweeps matmul tiles and per-compound on/off; under the
    # CPU interpreter it may well keep fusion off — candidate 0 is the
    # request as-given, so the selection never loses to not tuning
    with tempfile.TemporaryDirectory() as cache_dir:
        cf = be.compile(g.fn, CompileOptions(
            level="O2", use_pallas=True, interpret_pallas=True,
            autotune=True, cache_dir=cache_dir))
        print(f"autotuned knobs: mm tiles "
              f"({cf.options.mm_bm}, {cf.options.mm_bn}, "
              f"{cf.options.mm_bk}), "
              f"fuse_swiglu={cf.options.fuse_swiglu} "
              f"fuse_norm_matmul={cf.options.fuse_norm_matmul}")
        st = be.cache_stats()
        print(f"sweeps={st.autotune_sweeps} (a second process would "
              f"re-resolve from the record with zero)")


_TP_CHILD = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, "src")
import numpy as np
from repro.configs import get_config
from repro.launch.engine import EngineConfig, ServeEngine

cfg = get_config("deepseek-7b").reduced()
rng = np.random.default_rng(0)
prompt = rng.integers(0, cfg.vocab, size=(6,)).astype(np.int32)

def run(tp):
    eng = ServeEngine(cfg, EngineConfig(mode="paged", slots=2, max_len=24,
                                        seed=0, page_size=4, chunk_steps=4,
                                        tp=tp))
    rid = eng.submit(prompt, 8)
    rep = eng.run()
    return eng, rep, [int(t) for t in rep.results[rid]]

e1, r1, t1 = run(1)
e2, r2, t2 = run(2)
st = e2.cf.report.stats["partition"]
print(f"tp=1 tokens: {t1}")
print(f"tp=2 tokens: {t2}  (identical: {t1 == t2})")
print(f"partition stats: params_sharded={st['params_sharded']} "
      f"all_gather={st['all_gather']} "
      f"all_reduce={st.get('all_reduce', 0)} (exact profile: none)")
print(f"kv bytes/device: {r2.kv_bytes_per_device} at tp=2 vs "
      f"{r1.kv_bytes_per_device} at tp=1 "
      f"(global pool {e2.pool.total_bytes}B, each device holds "
      f"{cfg.n_kv_heads // 2}/{cfg.n_kv_heads} kv heads of every page)")
"""


def tensor_parallel_demo(cfg):
    import subprocess
    import sys

    from repro.backend import Backend, CompileOptions
    from repro.backend.sharding import partition_profile
    from repro.configs.base import ShapeConfig
    from repro.models.lm import build_graphs

    # one API: the pass profile names the mesh axes and the rule table
    prof = partition_profile("tp")
    print(f"profile 'tp': axes={prof.axes} rules={prof.rules} "
          f"last_dim_only={prof.last_dim_only} (column-parallel only: "
          f"never splits a contraction, so greedy decode is bit-exact)")
    # the partition pass runs inside Backend.compile; on a trivial (1,)
    # mesh it only annotates — the stats show what a real mesh would cut
    g = build_graphs(cfg, ShapeConfig("serve", "serve", 16, 2), 2)
    cf = Backend.create("jax", fresh=True).compile(
        g.fn, CompileOptions(mode="shardmap", partition="tp",
                             mesh_shape=(1,), static_jit=False))
    print(f"pipeline stats['partition']: "
          f"{dict(cf.report.stats['partition'])}")
    # the real 2-device serve needs the flag set before jax imports,
    # so it runs in a child process (exactly what CI's serving-tp does)
    proc = subprocess.run([sys.executable, "-c", _TP_CHILD],
                          capture_output=True, text=True, timeout=600)
    print(proc.stdout.rstrip() if proc.returncode == 0
          else f"tp subprocess failed:\n{proc.stderr[-2000:]}")


def main():
    cfg = get_config("deepseek-7b").reduced()
    engine = ServeEngine(cfg, slots=2, max_len=24, mode="continuous", seed=0)

    rng = np.random.default_rng(0)
    workload = [(rng.integers(0, cfg.vocab, size=(6,)), 8),
                (rng.integers(0, cfg.vocab, size=(4,)), 10),
                (rng.integers(0, cfg.vocab, size=(8,)), 6)]
    for prompt, max_new in workload:
        rid = engine.submit(prompt, max_new)
        print(f"submitted req{rid}: prompt={len(prompt)} gen={max_new}")

    print("--- streaming ---")
    for rid, token in engine.stream():
        print(f"req{rid} -> {token}")

    rep = engine.run()  # drained; returns the report
    print("--- report ---")
    print(f"{rep.generated_tokens} tokens, {rep.tok_s:.1f} tok/s e2e, "
          f"{rep.decode_tok_s:.1f} tok/s decode, "
          f"late admissions: {rep.late_admissions}")
    p = rep.pool
    print(f"kv pool: {p.slots} slots x {p.bytes_per_slot}B, "
          f"allocs={p.allocs} frees={p.frees} peak={p.peak_active}")
    kv_cont = rep.kv_bytes_per_active_token

    # --- paged mode: page-granular KV + chunked dispatch + sampling ---
    print("--- paged + sampling ---")
    paged = ServeEngine(cfg, slots=2, max_len=24, mode="paged", seed=0,
                        page_size=4, chunk_steps=4)
    greedy_rid = None
    for i, (prompt, max_new) in enumerate(workload):
        if i == 0:
            # stochastic request: reproducible via its PRNG key — resubmit
            # with the same key and you get the same tokens
            rid = paged.submit(prompt, max_new, temperature=0.8, top_k=16,
                               key=42)
            print(f"submitted req{rid}: temperature=0.8 top_k=16 key=42")
        else:
            rid = paged.submit(prompt, max_new)  # greedy (temperature 0)
            greedy_rid = rid
            print(f"submitted req{rid}: greedy")
    prep = paged.run()
    print(f"greedy req{greedy_rid} tokens: "
          f"{prep.results[greedy_rid].tolist()} "
          f"(identical to continuous mode)")
    pp = prep.pool
    print(f"paged pool: {pp.pages} pages x {pp.page_size} tokens, "
          f"peak {pp.peak_pages_in_use} in use, "
          f"page_allocs={pp.page_allocs} page_frees={pp.page_frees}, "
          f"fragmentation={pp.fragmentation:.2f}")
    print(f"kv bytes per active token: {prep.kv_bytes_per_active_token:.0f} "
          f"paged vs {kv_cont:.0f} continuous")

    # --- the HTTP front door: streaming clients over the network edge ---
    print("--- http server ---")
    engine = ServeEngine(cfg, slots=2, max_len=24, mode="paged", seed=0,
                         page_size=4, chunk_steps=4)
    with running_server(engine, max_wait_queue=4) as srv:
        print(f"listening on {srv.base_url}")
        # a token-ids client, streamed over SSE (chunked transfer)
        prompt, max_new = workload[0]
        res = asyncio.run(loadgen.stream_generate(
            srv.base_url, {"prompt": [int(t) for t in prompt],
                           "max_new": max_new, "tag": "demo"}))
        print(f"streamed {len(res.tokens)} tokens: {res.tokens} "
              f"(ttft {res.ttft_ms:.1f}ms)")
        # a text client: bytes folded into the vocabulary
        res = asyncio.run(loadgen.stream_generate(
            srv.base_url, {"text": "hello ngraph", "max_new": 6}))
        print(f"text prompt -> {res.tokens}")
        metrics = loadgen.fetch_json(srv.base_url, "/v1/metrics")
        s = metrics["server"]
        print(f"metrics: {s['requests_completed']} completed, "
              f"ttft p95 {s['ttft_p95_ms']:.1f}ms, "
              f"tok p95 {s['tok_p95_ms']:.2f}ms, "
              f"sustained {s['sustained_tok_s']:.1f} tok/s, "
              f"engine {metrics['engine']}")
    print(f"drained: drain_ok={srv.drain_ok} "
          f"pages_in_use={engine.pool.pages_in_use}")

    # --- fault tolerance: cancel, deadline, injected dispatch failure ---
    print("--- fault tolerance ---")
    from repro.launch.faults import FaultInjector

    eng = ServeEngine(cfg, slots=2, max_len=40, mode="paged", seed=0,
                      page_size=4, chunk_steps=1)
    ra = eng.submit(workload[0][0], 24)
    rb = eng.submit(workload[1][0], 8)
    eng.step()  # both admitted, first tokens decoded
    eng.cancel(ra, "user hit stop")
    eng.step()  # the chunk boundary where the cancel lands
    req = eng._requests[ra]
    print(f"cancelled req{ra}: status={req.status!r} "
          f"kept {len(req.tokens)} tokens, pool active={eng.pool.active} "
          f"pages_in_use={eng.pool.pages_in_use}")
    rd = eng.submit(workload[2][0], 24, deadline_s=30.0)
    eng.step()
    eng._requests[rd].deadline = 0.0  # force expiry for the demo
    rep = eng.run()
    print(f"deadline req{rd}: status={rep.statuses[rd]!r} "
          f"({rep.errors[rd]})")
    print(f"survivor req{rb}: status={rep.statuses[rb]!r}, "
          f"counters={rep.counters}")

    # inject a dispatch failure on a fresh engine: the in-flight request
    # fails with a structured error, the engine degrades but keeps serving
    eng = ServeEngine(cfg, slots=2, max_len=40, mode="paged", seed=0,
                      page_size=4, chunk_steps=1,
                      faults=FaultInjector("dispatch.raise=after:2"))
    ri = eng.submit(workload[0][0], 8)
    eng.step()
    eng.step()  # injected FaultError, contained
    print(f"injected req{ri}: status={eng._requests[ri].status!r} "
          f"health={eng.health!r}")
    rb2 = eng.submit(workload[1][0], 6)
    rep = eng.run()
    print(f"degraded engine still serves: req{rb2} -> "
          f"{rep.results[rb2].tolist()} "
          f"(pages_in_use={eng.pool.pages_in_use})")

    # --- prefix sharing: COW pages under a shared system prompt ---
    print("--- prefix sharing ---")
    sys_prompt = rng.integers(0, cfg.vocab, size=(16,)).astype(np.int32)

    def shared_run(sharing):
        # prefix_sharing defaults on for paged; prefill_chunk defaults
        # to 4 * page_size, so the 16-token prompt prefills in-graph
        eng = ServeEngine(cfg, slots=3, max_len=24, mode="paged", seed=0,
                          page_size=4, chunk_steps=2,
                          prefix_sharing=sharing)
        rids = [eng.submit(sys_prompt, 4) for _ in range(3)]
        return eng, rids, eng.run()

    eng, rids, rep = shared_run(True)
    p = rep.pool
    print(f"3 requests x one 16-token system prompt: "
          f"shared_attaches={p.shared_attaches} cow_copies={p.cow_copies} "
          f"peak {p.peak_pages_in_use} pages")
    _, urids, urep = shared_run(False)
    print(f"kv bytes per active token: "
          f"{rep.kv_bytes_per_active_token:.0f} shared vs "
          f"{urep.kv_bytes_per_active_token:.0f} unshared "
          f"(peak {urep.pool.peak_pages_in_use} pages)")
    same = all(np.array_equal(rep.results[s], urep.results[u])
               for s, u in zip(rids, urids))
    print(f"token parity with sharing off: {same}, drained "
          f"pages_in_use={eng.pool.pages_in_use}, "
          f"verify() -> {eng.pool.verify()}")

    # --- tensor-parallel serving through the unified sharding API ---
    print("--- tensor parallel ---")
    tensor_parallel_demo(cfg)

    # --- fused compound kernels + the autotuned knob resolution ---
    print("--- fused kernels ---")
    fused_kernel_demo(cfg)


if __name__ == "__main__":
    main()
