"""End-to-end LM training on the synthetic pipeline: a ~100M-param
llama-family model for a few hundred steps, with checkpointing and
fault-tolerance hooks (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main

if __name__ == "__main__":
    args = sys.argv[1:]
    if not any(a.startswith("--steps") for a in args):
        args += ["--steps", "300"]
    # ~100M params: deepseek-7b family, scaled width/depth
    sys.exit(main([
        "--arch", "deepseek-7b", "--reduced",
        "--batch", "16", "--seq", "128",
        "--ckpt-dir", "/tmp/repro_train_lm", "--ckpt-every", "100",
        "--log-every", "20",
    ] + args))
