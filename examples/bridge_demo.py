"""Framework bridges (paper sec. 3): the same model through three
frontends — neon-style layers, the functional builder, and a serialized
graph import — compiled by the same transformers.

    PYTHONPATH=src python examples/bridge_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import ng
from repro.bridges import neon, onnx_like
from repro.backend import Backend, available_backends
from repro.core import Function

rng = np.random.default_rng(0)

# Frontend 1: neon-style layer objects (the bridge owns the params)
net = neon.Sequential([
    neon.Dense(32, 64, activation="tanh", name="fc1", seed=1),
    neon.RMSNormLayer(64, name="norm"),
    neon.Dense(64, 10, name="fc2", seed=2),
])
model = neon.Model(net)
fn_neon, names = neon.bridge_to_ir(model, (4, 32))

# Frontend 2: the functional builder, same math
x = ng.parameter((4, 32), "f32", "input")
params = {n: ng.parameter(model.param_values[n].shape, "f32", n) for n in names}
h = ng.tanh(ng.matmul(x.out(), params["fc1/w"].out()) + params["fc1/b"].out())
h = ng.rms_norm(h, params["norm/g"].out())
y = ng.matmul(h, params["fc2/w"].out()) + params["fc2/b"].out()
fn_func = Function([x] + [params[n] for n in names], [y])

# Frontend 3: a serialized graph from a foreign producer
fn_import = onnx_like.import_graph(onnx_like.export_graph(fn_neon))

inp = rng.normal(size=(4, 32)).astype(np.float32)
args = [inp] + [model.param_values[n] for n in names]
print("backends:", available_backends())
for bname in ("interpreter", "jax"):
    be = Backend.create(bname)
    outs = [np.asarray(be.compile(f)(*args)[0])
            for f in (fn_neon, fn_func, fn_import)]
    print(f"{bname:12s} neon-vs-func {np.abs(outs[0]-outs[1]).max():.2e}  "
          f"neon-vs-import {np.abs(outs[0]-outs[2]).max():.2e}")
print("one IR, three frontends, two backends: identical numerics.")
